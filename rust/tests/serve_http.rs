//! HTTP transport round-trip: a real `TcpStream` client against
//! [`cct::serve::HttpServer`] fronting a live engine — `POST /infer`
//! (JSON and raw-f32 bodies, QoS headers) and `GET /stats`, plus the
//! error statuses (400 bad input, 404 unknown route, 504 expired
//! deadline).

use cct::net::parse_net;
use cct::serve::{HttpServer, ServeConfig, ServeEngine};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

const NET: &str = "
name: httptest
input: 1 8 8
conv { name: c1 out: 4 kernel: 3 pad: 1 std: 0.1 }
relu { name: r1 }
fc   { name: f1 out: 3 std: 0.1 }
";

const SAMPLE_LEN: usize = 64;

fn start() -> (ServeEngine, HttpServer) {
    let cfg = parse_net(NET).unwrap();
    let engine = ServeEngine::start(
        &cfg,
        ServeConfig { workers: 1, max_batch: 4, max_wait_us: 500, ..Default::default() },
    )
    .unwrap();
    let server = HttpServer::bind(engine.handle(), "127.0.0.1:0", 0).expect("bind ephemeral port");
    (engine, server)
}

/// Send one raw HTTP/1.1 request and return (status, body). The server
/// replies `Connection: close`, so read-to-end terminates.
fn request(addr: SocketAddr, head: &str, body: &[u8]) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(head.as_bytes()).expect("write head");
    stream.write_all(body).expect("write body");
    stream.flush().unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8_lossy(&raw).into_owned();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("unparseable response: {text:?}"));
    let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn post_infer(addr: SocketAddr, extra_headers: &str, body: &[u8], content_type: &str) -> (u16, String) {
    let head = format!(
        "POST /infer HTTP/1.1\r\nHost: cct\r\nContent-Type: {content_type}\r\n{extra_headers}Content-Length: {}\r\n\r\n",
        body.len()
    );
    request(addr, &head, body)
}

fn json_sample(value: f32) -> Vec<u8> {
    let mut parts = Vec::with_capacity(SAMPLE_LEN);
    for _ in 0..SAMPLE_LEN {
        parts.push(format!("{value}"));
    }
    format!("[{}]", parts.join(",")).into_bytes()
}

#[test]
fn infer_round_trip_json_and_binary_agree() {
    let (engine, server) = start();
    let addr = server.local_addr();

    // JSON body.
    let (status, body) = post_infer(addr, "", &json_sample(0.5), "application/json");
    assert_eq!(status, 200, "body: {body}");
    assert!(body.contains("\"class\":"), "{body}");
    assert!(body.contains("\"logits\":["), "{body}");
    assert!(body.contains("\"lane\":\"interactive\""), "{body}");

    // The same sample as raw little-endian f32 bytes must classify
    // identically (identical engine, identical input bits).
    let mut bin = Vec::with_capacity(SAMPLE_LEN * 4);
    for _ in 0..SAMPLE_LEN {
        bin.extend_from_slice(&0.5f32.to_le_bytes());
    }
    let (status2, body2) = post_infer(addr, "", &bin, "application/octet-stream");
    assert_eq!(status2, 200, "body: {body2}");
    let class = |b: &str| {
        b.split("\"class\":")
            .nth(1)
            .and_then(|s| s.split([',', '}']).next())
            .map(|s| s.to_string())
    };
    assert_eq!(class(&body), class(&body2), "JSON and binary bodies diverged");

    server.shutdown();
    let report = engine.shutdown();
    assert_eq!(report.completed, 2);
    assert!(report.worker_steady_allocs.iter().all(|&a| a == 0));
}

#[test]
fn qos_headers_route_lane_and_deadline() {
    let (engine, server) = start();
    let addr = server.local_addr();

    // Best-effort lane via header.
    let (status, body) = post_infer(
        addr,
        "X-Priority: best-effort\r\n",
        &json_sample(0.25),
        "application/json",
    );
    assert_eq!(status, 200, "body: {body}");
    assert!(body.contains("\"lane\":\"best_effort\""), "{body}");

    // A zero deadline is expired on arrival: shed as 504, no FLOPs.
    let (status, body) = post_infer(
        addr,
        "X-Deadline-Us: 0\r\n",
        &json_sample(0.25),
        "application/json",
    );
    assert_eq!(status, 504, "body: {body}");

    // An unknown priority is a client error.
    let (status, _) =
        post_infer(addr, "X-Priority: bulk\r\n", &json_sample(0.25), "application/json");
    assert_eq!(status, 400);

    server.shutdown();
    let report = engine.shutdown();
    assert_eq!(report.completed, 1);
    assert_eq!(report.expired, 1);
}

#[test]
fn stats_health_and_errors() {
    let (engine, server) = start();
    let addr = server.local_addr();

    // Serve one request so /stats has something to report.
    let (status, _) = post_infer(addr, "", &json_sample(1.0), "application/json");
    assert_eq!(status, 200);

    let (status, body) = request(addr, "GET /stats HTTP/1.1\r\nHost: cct\r\n\r\n", b"");
    assert_eq!(status, 200, "body: {body}");
    assert!(body.contains("\"completed\":1"), "{body}");
    assert!(body.contains("\"lanes\":"), "{body}");
    // Workers report their steady-state alloc counters at exit, so a
    // live snapshot legitimately shows an empty array.
    assert!(body.contains("\"worker_steady_allocs\":["), "{body}");

    let (status, body) = request(addr, "GET /healthz HTTP/1.1\r\nHost: cct\r\n\r\n", b"");
    assert_eq!(status, 200);
    assert!(body.contains("\"ok\":true"), "{body}");

    // Wrong sample length → 400 naming both lengths.
    let (status, body) = post_infer(addr, "", b"[1,2,3]", "application/json");
    assert_eq!(status, 400);
    assert!(body.contains("expected 64"), "{body}");

    // Malformed body → 400; unknown route → 404.
    let (status, _) = post_infer(addr, "", b"not json", "application/json");
    assert_eq!(status, 400);
    let (status, _) = request(addr, "GET /nope HTTP/1.1\r\nHost: cct\r\n\r\n", b"");
    assert_eq!(status, 404);

    server.shutdown();
    engine.shutdown();
}
