//! Determinism, parity, and staleness stress tests for the Hogwild-style
//! async coordinator (`cct::coordinator::hogwild`).
//!
//! The contract under test, in order of strength:
//!
//! 1. `S = 0` is *bit-identical* to the synchronous coordinator — round
//!    losses, final weights, and eval logits — at 1, 2, and 8 workers.
//!    Both paths run the same `merge_update_broadcast`, so any
//!    divergence is a real bug, not FP noise.
//! 2. `S > 0` honors the staleness bound: no worker ever observes a lag
//!    greater than `S`, every worker's every round lands exactly one
//!    shared-model update, and the run still converges to within a
//!    loose tolerance of the sync trajectory.
//! 3. The round loop is allocation-free after warm-up: the per-run
//!    report carries tensor-alloc and GEMM-arena counters sampled after
//!    round 0, and both must read zero.

use cct::coordinator::{partitioner, AsyncConfig, AsyncCoordinator, CnnCoordinator};
use cct::layers::{ExecCtx, Phase};
use cct::net::config::parse_net;
use cct::rng::Pcg64;
use cct::solver::SolverConfig;
use cct::tensor::Tensor;

const TINY: &str = r#"
name: tiny
input: 1 8 8
conv { name: c1 out: 4 kernel: 3 pad: 1 std: 0.1 }
relu { name: r1 }
fc   { name: f1 out: 3 std: 0.1 }
"#;

fn tiny_corpus(n: usize, seed: u64) -> (Tensor, Vec<usize>) {
    let mut rng = Pcg64::new(seed);
    let x = Tensor::randn((n, 1, 8, 8), 0.0, 1.0, &mut rng);
    let labels = (0..n).map(|i| i % 3).collect();
    (x, labels)
}

fn solver_cfg() -> SolverConfig {
    SolverConfig { base_lr: 0.05, momentum: 0.9, weight_decay: 0.0, ..Default::default() }
}

fn async_coord(workers: usize, staleness: usize, seed: u64) -> AsyncCoordinator {
    let cfg = parse_net(TINY).unwrap();
    AsyncCoordinator::new(&cfg, AsyncConfig { workers, total_threads: workers, staleness, seed }, solver_cfg())
        .unwrap()
}

/// Drive the synchronous coordinator over the same cycling corpus
/// windows `AsyncCoordinator::run` uses, returning per-round losses.
fn run_sync(
    coord: &mut CnnCoordinator,
    x: &Tensor,
    labels: &[usize],
    batch: usize,
    rounds: usize,
) -> Vec<f64> {
    (0..rounds)
        .map(|r| {
            let s = partitioner::round_start(labels.len(), batch, r);
            coord.step(&x.slice_samples(s, s + batch), &labels[s..s + batch])
        })
        .collect()
}

#[test]
fn s0_bit_identical_to_sync_at_1_2_8_workers() {
    let (x, labels) = tiny_corpus(16, 3);
    let (ex, _) = tiny_corpus(8, 21);
    let batch = 8;
    let rounds = 5;
    for workers in [1usize, 2, 8] {
        let cfg = parse_net(TINY).unwrap();
        let mut sync = CnnCoordinator::new(&cfg, workers, workers, solver_cfg(), 7).unwrap();
        let sync_losses = run_sync(&mut sync, &x, &labels, batch, rounds);

        let mut ac = async_coord(workers, 0, 7);
        let rep = ac.run(&x, &labels, batch, rounds);

        assert_eq!(rep.rounds, rounds);
        assert_eq!(rep.max_observed_lag, 0, "S=0 must be fully synchronous ({workers} workers)");
        for (r, (a, s)) in rep.round_loss.iter().zip(sync_losses.iter()).enumerate() {
            assert_eq!(a.to_bits(), s.to_bits(), "{workers} workers, round {r}: async {a} vs sync {s}");
        }
        for (i, (pa, ps)) in ac.net().params().iter().zip(sync.net().params().iter()).enumerate() {
            assert_eq!(pa.data.as_slice(), ps.data.as_slice(), "{workers} workers: param blob {i} diverged");
        }
        // Logits on a held-out batch must also match to the bit.
        let test_ctx = ExecCtx { phase: Phase::Test, ..Default::default() };
        let la = ac.net().forward(&ex, &test_ctx);
        let ls = sync.net().forward(&ex, &test_ctx);
        for (j, (a, s)) in la.as_slice().iter().zip(ls.as_slice().iter()).enumerate() {
            assert_eq!(a.to_bits(), s.to_bits(), "{workers} workers: logit {j} diverged");
        }
    }
}

#[test]
fn s_positive_stress_honors_bound_and_converges() {
    let (x, labels) = tiny_corpus(32, 5);
    let batch = 16;
    let rounds = 20;
    let staleness = 3;

    let cfg = parse_net(TINY).unwrap();
    let mut sync = CnnCoordinator::new(&cfg, 8, 8, solver_cfg(), 7).unwrap();
    let sync_losses = run_sync(&mut sync, &x, &labels, batch, rounds);
    let sync_final = *sync_losses.last().unwrap();

    let mut ac = async_coord(8, staleness, 7);
    let rep = ac.run(&x, &labels, batch, rounds);

    assert_eq!(rep.active_workers, 8);
    assert_eq!(rep.staleness, staleness);
    assert!(
        rep.max_observed_lag <= staleness,
        "observed lag {} exceeds bound {staleness}",
        rep.max_observed_lag
    );
    // Every worker commits exactly one shared update per round.
    assert_eq!(rep.updates, 8 * rounds);
    assert!(rep.round_loss.iter().all(|l| l.is_finite()));

    // Convergence within a deliberately loose tolerance of sync: the
    // trajectories differ (stale reads reorder updates) but a bounded-
    // staleness run must still descend and must not diverge from the
    // synchronous optimum region.
    let first = rep.round_loss[0];
    assert!(rep.final_loss < first * 0.9, "async S={staleness} failed to descend: {first:.4} → {:.4}", rep.final_loss);
    assert!(
        (rep.final_loss - sync_final).abs() < 0.75,
        "async final {:.4} strayed from sync final {sync_final:.4}",
        rep.final_loss
    );
}

#[test]
fn async_round_loop_is_allocation_free_after_warmup() {
    // ISSUE acceptance: zero steady-state tensor allocations in async
    // training. The report counters are sampled after round 0 (workers)
    // and after the first merge (S=0 scheduler), so any allocation in
    // the steady round loop shows up here.
    let (x, labels) = tiny_corpus(16, 13);
    for staleness in [0usize, 2] {
        let mut ac = async_coord(4, staleness, 9);
        let rep = ac.run(&x, &labels, 8, 8);
        assert_eq!(
            rep.steady_tensor_allocs, 0,
            "tensor allocations in the steady round loop (S={staleness})"
        );
        assert_eq!(
            rep.steady_arena_growth, 0,
            "GEMM packing arena grew in the steady round loop (S={staleness})"
        );
    }
}

#[test]
fn s0_run_is_repeatable_bit_for_bit() {
    // Same seed, same data, two fresh coordinators: identical loss
    // trajectory. Cheap but catches any nondeterminism sneaking into
    // the worker scheduling at S=0.
    let (x, labels) = tiny_corpus(12, 17);
    let run = || {
        let mut ac = async_coord(2, 0, 23);
        ac.run(&x, &labels, 6, 6).round_loss.iter().map(|l| l.to_bits()).collect::<Vec<u64>>()
    };
    assert_eq!(run(), run());
}
