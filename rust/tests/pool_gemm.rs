//! Persistent-pool GEMM guarantees (PR 5):
//!
//! * parity with `gemm_naive` across degenerate shapes
//!   (m/n/k ∈ {0, 1, odd primes}) and all transpose combinations;
//! * **bitwise** parity with the single-threaded blocked kernel — tile
//!   scheduling must not change a single ulp;
//! * deterministic results under pool contention (many submitter
//!   threads hammering the shared pool concurrently);
//! * zero steady-state allocations: no tensor allocs and no packing-
//!   arena growth on a warmed thread;
//! * all pool worker threads joined on drop, procfs-asserted.

use cct::gemm::{
    gemm_blocked, gemm_naive, gemm_spawn, gemm_threaded, pool, sgemm, BlockSizes, GemmDims,
    GemmPool, Trans,
};
use cct::rng::Pcg64;
use cct::tensor::alloc_stats;

fn rand_vec(n: usize, rng: &mut Pcg64) -> Vec<f32> {
    let mut v = vec![0f32; n];
    rng.fill_uniform(&mut v, -1.0, 1.0);
    v
}

/// m/n/k ∈ {0, 1, odd primes}: every combination, every transpose,
/// α/β active, pool vs naive.
#[test]
fn degenerate_and_prime_shapes_match_naive() {
    let pool = GemmPool::new(2);
    let sizes = [0usize, 1, 3, 7, 13, 31];
    let mut rng = Pcg64::new(7001);
    for &m in &sizes {
        for &n in &sizes {
            for &k in &sizes {
                let dims = GemmDims { m, n, k };
                for &ta in &[Trans::N, Trans::T] {
                    for &tb in &[Trans::N, Trans::T] {
                        let a = rand_vec(m * k, &mut rng);
                        let b = rand_vec(k * n, &mut rng);
                        let mut c0 = rand_vec(m * n, &mut rng);
                        let mut c1 = c0.clone();
                        gemm_naive(ta, tb, dims, 1.25, &a, &b, 0.5, &mut c0);
                        pool.gemm(ta, tb, dims, 1.25, &a, &b, 0.5, &mut c1, 4);
                        for (i, (x, y)) in c0.iter().zip(c1.iter()).enumerate() {
                            assert!(
                                (x - y).abs() < 1e-3,
                                "m={m} n={n} k={k} ta={ta:?} tb={tb:?} idx {i}: {x} vs {y}"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Pool tiles must reproduce the single-threaded blocked kernel
/// bit-for-bit: same packing layout, same KC panel walk, same
/// accumulation order per element, no matter how the tile grid is cut
/// or which worker claims which tile.
#[test]
fn pool_is_bitwise_identical_to_blocked() {
    let pool = GemmPool::new(3);
    let mut rng = Pcg64::new(7002);
    for &(m, n, k) in &[(311usize, 257usize, 199usize), (64, 2400, 96), (529, 256, 300)] {
        let dims = GemmDims { m, n, k };
        let a = rand_vec(m * k, &mut rng);
        let b = rand_vec(k * n, &mut rng);
        let mut want = rand_vec(m * n, &mut rng);
        let mut got = want.clone();
        gemm_blocked(Trans::N, Trans::N, dims, 1.5, &a, &b, 0.25, &mut want, BlockSizes::default());
        pool.gemm(Trans::N, Trans::N, dims, 1.5, &a, &b, 0.25, &mut got, 4);
        for (i, (x, y)) in want.iter().zip(got.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "({m},{n},{k}) idx {i}: {x} vs {y}");
        }
    }
}

/// Several OS threads hammer the *shared* pool concurrently (the serve
/// worker pattern): every result must be bit-identical to the
/// single-threaded reference — run-lock serialization plus disjoint
/// tiles leave no room for scheduling-dependent results.
#[test]
fn contended_pool_results_are_deterministic() {
    let dims = GemmDims { m: 260, n: 130, k: 90 };
    let mut rng = Pcg64::new(7003);
    let a = rand_vec(dims.m * dims.k, &mut rng);
    let b = rand_vec(dims.k * dims.n, &mut rng);
    let mut want = vec![0f32; dims.m * dims.n];
    gemm_blocked(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut want, BlockSizes::default());

    std::thread::scope(|scope| {
        for _ in 0..4 {
            let (a, b, want) = (&a, &b, &want);
            scope.spawn(move || {
                for _ in 0..8 {
                    let mut c = vec![0f32; dims.m * dims.n];
                    gemm_threaded(Trans::N, Trans::N, dims, 1.0, a, b, 0.0, &mut c, 4);
                    for (i, (x, y)) in want.iter().zip(c.iter()).enumerate() {
                        assert_eq!(x.to_bits(), y.to_bits(), "idx {i} under contention");
                    }
                }
            });
        }
    });
}

/// Multiple submitter threads share ONE pool object and submit
/// concurrently — the publication path the soundness CI's TSan job
/// watches: each job's results must be published to its submitter by
/// the `tasks_done` Acquire/Release handshake, and the run lock must
/// keep jobs from interleaving. Any missing happens-before edge shows
/// up as a data race under TSan or as a bitwise mismatch here.
#[test]
#[cfg_attr(miri, ignore = "heavy cross-thread schedule space; covered by the lib-level pool tests")]
fn shared_pool_submitters_race_safely() {
    let pool = GemmPool::new(3);
    let dims = GemmDims { m: 190, n: 96, k: 64 };
    let mut rng = Pcg64::new(7007);
    let a = rand_vec(dims.m * dims.k, &mut rng);
    let b = rand_vec(dims.k * dims.n, &mut rng);
    let mut want = vec![0f32; dims.m * dims.n];
    gemm_blocked(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut want, BlockSizes::default());

    std::thread::scope(|scope| {
        for _ in 0..4 {
            let (pool, a, b, want) = (&pool, &a, &b, &want);
            scope.spawn(move || {
                for _ in 0..6 {
                    let mut c = vec![0f32; dims.m * dims.n];
                    pool.gemm(Trans::N, Trans::N, dims, 1.0, a, b, 0.0, &mut c, 4);
                    for (i, (x, y)) in want.iter().zip(c.iter()).enumerate() {
                        assert_eq!(x.to_bits(), y.to_bits(), "idx {i} under submitter contention");
                    }
                }
            });
        }
    });
}

/// The spawn-per-call baseline and the pool agree (they are compared
/// head-to-head by the fig2 bench, so both must stay correct).
#[test]
fn spawn_baseline_matches_pool() {
    let dims = GemmDims { m: 150, n: 70, k: 60 };
    let mut rng = Pcg64::new(7004);
    let a = rand_vec(dims.m * dims.k, &mut rng);
    let b = rand_vec(dims.k * dims.n, &mut rng);
    let mut c_spawn = vec![0.5f32; dims.m * dims.n];
    let mut c_pool = c_spawn.clone();
    gemm_spawn(Trans::N, Trans::N, dims, 1.0, &a, &b, 1.0, &mut c_spawn, 4);
    sgemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 1.0, &mut c_pool, 4);
    for (x, y) in c_spawn.iter().zip(c_pool.iter()) {
        assert!((x - y).abs() < 1e-3, "{x} vs {y}");
    }
}

/// Steady-state pooled GEMM performs zero tensor allocations and zero
/// packing-arena growth on a warmed submitter thread (worker arenas
/// are planned at spawn and can never grow past their warm size).
#[test]
fn steady_state_is_allocation_free() {
    let pool = GemmPool::new(2);
    let dims = GemmDims { m: 530, n: 256, k: 310 };
    let mut rng = Pcg64::new(7005);
    let a = rand_vec(dims.m * dims.k, &mut rng);
    let b = rand_vec(dims.k * dims.n, &mut rng);
    let mut c = vec![0f32; dims.m * dims.n];
    pool::warm_local();
    pool.gemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut c, 4); // warm-up call
    let arena_snap = pool::arena_allocs();
    let tensor_snap = alloc_stats::tensor_allocs();
    for _ in 0..10 {
        pool.gemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut c, 4);
    }
    assert_eq!(pool::arena_allocs() - arena_snap, 0, "packing arena grew in steady state");
    assert_eq!(
        alloc_stats::allocs_since(tensor_snap),
        0,
        "tensor allocations in the GEMM hot loop"
    );
}

/// Dropping a pool joins every worker thread — procfs-asserted by
/// counting live threads with this pool's unique name prefix.
#[cfg(target_os = "linux")]
#[test]
#[cfg_attr(miri, ignore = "asserts on procfs thread names, which Miri's isolation hides")]
fn pool_workers_join_on_drop() {
    let pool = GemmPool::new(3);
    let prefix = pool.thread_name_prefix();
    // Thread names are set by the spawned threads themselves; wait for
    // all three to appear before asserting.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        match pool::threads_with_prefix(&prefix) {
            Some(3) => break,
            Some(_) if std::time::Instant::now() < deadline => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Some(got) => panic!("expected 3 pool threads named {prefix}*, found {got}"),
            None => return, // procfs unavailable — nothing to assert
        }
    }
    // Exercise the pool so workers have actually run jobs.
    let dims = GemmDims { m: 200, n: 64, k: 40 };
    let mut rng = Pcg64::new(7006);
    let a = rand_vec(dims.m * dims.k, &mut rng);
    let b = rand_vec(dims.k * dims.n, &mut rng);
    let mut c = vec![0f32; dims.m * dims.n];
    pool.gemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut c, 4);

    drop(pool);
    // Drop joins synchronously, so the count is 0 immediately.
    assert_eq!(
        pool::threads_with_prefix(&prefix),
        Some(0),
        "pool worker threads leaked past drop"
    );
}

/// `parallel_for` under a thread budget of 1 must not touch the pool
/// (budget semantics), and with a budget > 1 must run every task
/// exactly once.
#[test]
fn parallel_for_budget_semantics() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let slots: Vec<AtomicUsize> = (0..40).map(|_| AtomicUsize::new(0)).collect();
    pool::parallel_for(1, slots.len(), &|t| {
        slots[t].fetch_add(1, Ordering::Relaxed);
    });
    pool::parallel_for(4, slots.len(), &|t| {
        slots[t].fetch_add(1, Ordering::Relaxed);
    });
    for (i, s) in slots.iter().enumerate() {
        assert_eq!(s.load(Ordering::Relaxed), 2, "task {i}");
    }
}
