//! Integration tests for the shape-keyed GEMM autotuner (PR 10):
//! numeric parity of tuned vs untuned dispatch, cache persistence
//! round-trips, and `CCT_TUNE=off` determinism.
//!
//! These tests flip the process-global tune mode, which the lib's unit
//! tests never do — that is why they live in their own test binary
//! (own process), serialized through a local mutex.

use cct::gemm::{gemm_blocked, gemm_naive, sgemm, tune, BlockSizes, GemmDims, Trans};
use cct::rng::Pcg64;
use std::sync::{Mutex, PoisonError};

/// Serializes tests: each one mutates the global tune mode and cache.
static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

fn rand_operands(dims: GemmDims, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::new(seed);
    let mut a = vec![0f32; dims.m * dims.k];
    let mut b = vec![0f32; dims.k * dims.n];
    rng.fill_uniform(&mut a, -1.0, 1.0);
    rng.fill_uniform(&mut b, -1.0, 1.0);
    (a, b)
}

/// Shapes chosen to stress every edge path: prime dims (all blocking
/// remainders non-trivial), a single-row problem, a single-column
/// problem.
const AWKWARD: [GemmDims; 3] = [
    GemmDims { m: 37, n: 29, k: 41 },
    GemmDims { m: 1, n: 257, k: 31 },
    GemmDims { m: 127, n: 1, k: 64 },
];

#[test]
fn tuned_matches_untuned_on_awkward_shapes() {
    let _g = guard();
    tune::set_mode(tune::TuneMode::On);
    for (i, &dims) in AWKWARD.iter().enumerate() {
        let (a, b) = rand_operands(dims, 900 + i as u64);
        // Untuned reference (mode off → analytic default path).
        tune::set_mode(tune::TuneMode::Off);
        let mut want = vec![0.25f32; dims.m * dims.n];
        sgemm(Trans::N, Trans::N, dims, 1.1, &a, &b, 0.4, &mut want, 1);
        // Tune, then dispatch through the cached decision.
        tune::set_mode(tune::TuneMode::On);
        let d = tune::tune_gemm(dims, 1);
        assert!(d.seconds <= d.default_seconds, "{dims:?}: winner slower than default");
        let mut got = vec![0.25f32; dims.m * dims.n];
        sgemm(Trans::N, Trans::N, dims, 1.1, &a, &b, 0.4, &mut got, 1);
        for (x, y) in want.iter().zip(got.iter()) {
            assert!((x - y).abs() < 1e-3, "{dims:?}: {x} vs {y}");
        }
        // A fixed cached strategy is bitwise deterministic call-to-call.
        let mut again = vec![0.25f32; dims.m * dims.n];
        sgemm(Trans::N, Trans::N, dims, 1.1, &a, &b, 0.4, &mut again, 1);
        for (x, y) in got.iter().zip(again.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{dims:?}: tuned dispatch not reproducible");
        }
    }
}

#[test]
fn degenerate_dims_quick_return_under_tuning() {
    let _g = guard();
    tune::set_mode(tune::TuneMode::On);
    for &(m, n, k) in &[(0usize, 8usize, 8usize), (8, 0, 8), (8, 8, 0)] {
        let dims = GemmDims { m, n, k };
        let _ = tune::tune_gemm(dims, 1); // must not panic or cache
        assert!(tune::lookup(dims, 1).is_none());
        let mut c = vec![7f32; m * n];
        sgemm(Trans::N, Trans::N, dims, 1.0, &[], &[], 1.0, &mut c, 1);
        assert!(c.iter().all(|&x| x == 7.0), "({m},{n},{k}) touched C");
    }
}

#[test]
fn cache_file_round_trips_identical_decisions() {
    let _g = guard();
    tune::set_mode(tune::TuneMode::On);
    let shapes = [GemmDims { m: 53, n: 37, k: 23 }, GemmDims { m: 19, n: 71, k: 43 }];
    let before: Vec<_> = shapes
        .iter()
        .map(|&d| (d, tune::tune_gemm(d, 1).strategy))
        .collect();
    let path = std::env::temp_dir().join("cct_tune_cache_roundtrip.json");
    let path = path.to_str().expect("temp path is utf-8");
    tune::save_to(path).expect("cache file written");
    tune::clear();
    for &(d, _) in &before {
        assert!(tune::lookup(d, 1).is_none(), "clear() left {d:?} cached");
    }
    let loaded = tune::load_from(path).expect("cache file reloads");
    assert!(loaded >= shapes.len(), "expected ≥ {} entries, loaded {loaded}", shapes.len());
    for (d, strategy) in before {
        assert_eq!(tune::lookup(d, 1), Some(strategy), "{d:?}: decision changed across the round trip");
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn off_mode_is_bitwise_identical_to_untuned_default() {
    let _g = guard();
    tune::set_mode(tune::TuneMode::On);
    // Ensure a cached decision exists so Off actually has something to
    // ignore.
    let dims = GemmDims { m: 61, n: 47, k: 29 };
    let _ = tune::tune_gemm(dims, 1);
    tune::set_mode(tune::TuneMode::Off);
    let (a, b) = rand_operands(dims, 1234);
    let mut via_sgemm = vec![0f32; dims.m * dims.n];
    sgemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut via_sgemm, 1);
    let mut via_blocked = vec![0f32; dims.m * dims.n];
    gemm_blocked(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut via_blocked, BlockSizes::default());
    for (x, y) in via_sgemm.iter().zip(via_blocked.iter()) {
        assert_eq!(x.to_bits(), y.to_bits(), "CCT_TUNE=off must run the analytic default exactly");
    }
    tune::set_mode(tune::TuneMode::On);
}

#[test]
// Dispatches to the process-wide pool, whose workers outlive the
// harness — a thread leak under Miri.
#[cfg_attr(miri, ignore)]
fn threaded_tuned_dispatch_matches_naive() {
    let _g = guard();
    tune::set_mode(tune::TuneMode::On);
    let dims = GemmDims { m: 131, n: 67, k: 73 };
    let d = tune::tune_gemm(dims, 4);
    assert!(d.seconds <= d.default_seconds);
    assert_eq!(tune::lookup(dims, 4), Some(d.strategy));
    let (a, b) = rand_operands(dims, 4321);
    let mut want = vec![0f32; dims.m * dims.n];
    gemm_naive(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut want);
    let mut got = vec![0f32; dims.m * dims.n];
    sgemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut got, 4);
    for (x, y) in want.iter().zip(got.iter()) {
        assert!((x - y).abs() < 1e-3, "{x} vs {y}");
    }
    // Bitwise stable across repeated tuned dispatches, pooled or not.
    let mut again = vec![0f32; dims.m * dims.n];
    sgemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut again, 4);
    for (x, y) in got.iter().zip(again.iter()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}
