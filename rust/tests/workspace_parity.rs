//! Workspace-execution guarantees:
//!
//! 1. **Parity** — the planned-workspace path produces bit-identical
//!    results (forward activations, loss, every parameter gradient) to
//!    the classic allocating path (driving each layer's `forward` /
//!    `backward` wrapper by hand, the pre-workspace algorithm).
//! 2. **No growth** — two consecutive steps reuse the same arena: same
//!    byte footprint, same buffer addresses.
//! 3. **Zero allocation** — `Net::forward_backward` (and a full
//!    solver step) performs zero tensor allocations after the first
//!    step at a fixed batch size, asserted via the
//!    `tensor::alloc_stats` hook.

use cct::layers::conv::ConvConfig;
use cct::layers::{
    ConvLayer, DropoutLayer, ExecCtx, FcLayer, Layer, LrnLayer, PoolLayer, PoolMode, ReluLayer,
    SoftmaxLossLayer,
};
use cct::net::{parse_net, config::build_net, Net};
use cct::rng::Pcg64;
use cct::solver::{SgdSolver, SolverConfig};
use cct::tensor::{alloc_stats, Tensor};

/// The tiny test architecture, built twice from identical seeds: once
/// as loose layers (manual drive) and once as a [`Net`].
fn tiny_layers(seed: u64) -> (ConvLayer, ReluLayer, DropoutLayer, PoolLayer, FcLayer) {
    let mut rng = Pcg64::new(seed);
    let conv = ConvLayer::new(
        "conv1",
        1,
        ConvConfig { out_channels: 4, kernel: 3, pad: 1, weight_std: 0.1, ..Default::default() },
        &mut rng,
    );
    let fc = FcLayer::new("fc", 4 * 4 * 4, 3, 0.1, &mut rng);
    (
        conv,
        ReluLayer::new("relu1"),
        DropoutLayer::new("drop1", 0.3),
        PoolLayer::new("pool1", PoolMode::Max, 2, 2, 0),
        fc,
    )
}

fn tiny_net(seed: u64) -> Net {
    let (conv, relu, drop, pool, fc) = tiny_layers(seed);
    let layers: Vec<Box<dyn Layer>> = vec![
        Box::new(conv),
        Box::new(relu),
        Box::new(drop),
        Box::new(pool),
        Box::new(fc),
    ];
    Net::new("tiny", (1, 8, 8), layers, vec![true, false, false, false, false])
}

#[test]
fn workspace_path_matches_allocating_path_bit_for_bit() {
    let ctx = ExecCtx { seed: 17, ..Default::default() };
    let mut rng = Pcg64::new(99);
    let x = Tensor::randn((2, 1, 8, 8), 0.0, 1.0, &mut rng);
    let labels = [0usize, 2];

    // --- workspace path (the Net) --------------------------------
    let mut net = tiny_net(42);
    let net_loss = net.forward_backward(&x, &labels, &ctx);
    let net_logits = net.forward(&x, &ctx);

    // --- classic allocating path (manual layer drive) ------------
    let (mut conv, mut relu, mut drop, mut pool, mut fc) = tiny_layers(42);
    let mut loss_layer = SoftmaxLossLayer::new("loss");
    let a1 = conv.forward(&x, &ctx);
    let a2 = relu.forward(&a1, &ctx);
    let a3 = drop.forward(&a2, &ctx);
    let a4 = pool.forward(&a3, &ctx);
    let logits = fc.forward(&a4, &ctx);
    loss_layer.set_labels(&labels);
    let manual_loss = loss_layer.forward_loss(&logits);
    let mut g = Tensor::zeros(*logits.shape());
    loss_layer.backward_logits(&mut g);
    let g = fc.backward(&a4, &g, &ctx);
    let g = pool.backward(&a3, &g, &ctx);
    let g = drop.backward(&a2, &g, &ctx);
    let g = relu.backward(&a1, &g, &ctx);
    let _ = conv.backward(&x, &g, &ctx);

    // --- bit-for-bit comparison ----------------------------------
    assert_eq!(net_loss.to_bits(), manual_loss.to_bits(), "{net_loss} vs {manual_loss}");
    assert_eq!(net_logits.as_slice(), logits.as_slice(), "forward activations diverge");
    let manual_params: Vec<Vec<f32>> = [
        conv.params(), fc.params(),
    ]
    .iter()
    .flatten()
    .map(|p| p.grad.as_slice().to_vec())
    .collect();
    let mut net_params = net.params_mut();
    assert_eq!(net_params.len(), manual_params.len());
    for (np, mp) in net_params.iter_mut().zip(manual_params.iter()) {
        assert_eq!(np.grad.as_slice(), &mp[..], "parameter gradients diverge");
    }
}

#[test]
fn consecutive_steps_reuse_the_arena() {
    let ctx = ExecCtx { seed: 3, ..Default::default() };
    let mut rng = Pcg64::new(7);
    let x = Tensor::randn((4, 1, 8, 8), 0.0, 1.0, &mut rng);
    let labels = [0usize, 1, 2, 0];

    let mut net = tiny_net(5);
    let mut ws = net.plan(4);
    ws.load_input(&x);
    let bytes0 = ws.bytes();
    let slots0 = ws.num_slots();
    let ptr0 = ws.logits().as_slice().as_ptr();
    let l1 = net.forward_backward_in(&mut ws, &labels, &ctx);
    let l2 = net.forward_backward_in(&mut ws, &labels, &ctx);
    assert!(l1.is_finite() && l2.is_finite());
    assert_eq!(ws.bytes(), bytes0, "arena grew across steps");
    assert_eq!(ws.num_slots(), slots0);
    assert_eq!(ws.logits().as_slice().as_ptr(), ptr0, "arena buffers were reallocated");
}

#[test]
fn forward_backward_is_allocation_free_after_first_step() {
    // The acceptance criterion: zero tensor allocations after the
    // first step for a fixed batch size — including the solver update,
    // and on a net exercising every layer kind (conv, relu, lrn, pool,
    // fc, dropout + the softmax loss).
    const NET: &str = "
name: alllayers
input: 3 16 16
conv { name: c1 out: 8 kernel: 3 pad: 1 std: 0.1 }
relu { name: r1 }
lrn  { name: n1 size: 3 }
pool { name: p1 mode: max kernel: 2 stride: 2 }
fc   { name: f1 out: 16 std: 0.1 }
relu { name: r2 }
dropout { name: d1 p: 0.5 }
fc   { name: f2 out: 5 std: 0.1 }
softmax { name: loss }
";
    let cfg = parse_net(NET).unwrap();
    let mut rng = Pcg64::new(21);
    let mut net = build_net(&cfg, &mut rng).unwrap();
    let mut solver = SgdSolver::new(SolverConfig::default());
    let x = Tensor::randn((4, 3, 16, 16), 0.0, 1.0, &mut rng);
    let labels = [0usize, 1, 2, 3];
    let ctx = ExecCtx::default();

    // first step plans the workspace (+ solver momentum buffers)
    solver.train_step(&mut net, &x, &labels, &ctx);
    // second step: steady state
    solver.train_step(&mut net, &x, &labels, &ctx);

    let snap = alloc_stats::tensor_allocs();
    for _ in 0..3 {
        solver.train_step(&mut net, &x, &labels, &ctx);
    }
    assert_eq!(
        alloc_stats::allocs_since(snap),
        0,
        "training hot loop allocated tensors after warm-up"
    );

    // changing the batch size re-plans (allocates), then settles again
    let x2 = Tensor::randn((2, 3, 16, 16), 0.0, 1.0, &mut rng);
    net.forward_backward(&x2, &[0, 1], &ctx);
    net.forward_backward(&x2, &[0, 1], &ctx);
    let snap2 = alloc_stats::tensor_allocs();
    net.forward_backward(&x2, &[0, 1], &ctx);
    assert_eq!(alloc_stats::allocs_since(snap2), 0);
}

#[test]
fn inplace_layers_share_slots_and_still_learn() {
    // A net dominated by in-place layers must still converge — guards
    // against aliasing bugs in the shared-slot backward chain
    // (relu→dropout sharing one activation slot).
    let mut net = tiny_net(11);
    let mut rng = Pcg64::new(13);
    let x = Tensor::randn((6, 1, 8, 8), 0.0, 1.0, &mut rng);
    let labels = [0usize, 1, 2, 0, 1, 2];
    let mut solver = SgdSolver::new(SolverConfig {
        base_lr: 0.05,
        momentum: 0.9,
        weight_decay: 0.0,
        ..Default::default()
    });
    let mut ws = net.plan(6);
    let ctx = ExecCtx { seed: 1, ..Default::default() };
    ws.load_input(&x);
    let first = solver.train_step_in(&mut net, &mut ws, &labels, &ctx);
    let mut last = first;
    for _ in 0..40 {
        ws.load_input(&x);
        last = solver.train_step_in(&mut net, &mut ws, &labels, &ctx);
    }
    assert!(last < first * 0.7, "in-place net did not learn: {first} → {last}");
}

#[test]
fn lrn_backward_through_workspace_matches_wrapper() {
    // LRN caches its scale tensor between forward and backward; make
    // sure the workspace drive (scratch-planned) agrees with the
    // allocating wrapper drive.
    let mut rng = Pcg64::new(31);
    let x = Tensor::randn((2, 5, 3, 3), 0.0, 1.0, &mut rng);
    let dy = Tensor::randn(*x.shape(), 0.0, 1.0, &mut rng);
    let ctx = ExecCtx::default();

    let mut a = LrnLayer::new("n", 3, 0.5, 0.75, 1.0);
    let ya = a.forward(&x, &ctx);
    let da = a.backward(&x, &dy, &ctx);

    let mut b = LrnLayer::new("n", 3, 0.5, 0.75, 1.0);
    let mut scratch = b.plan_scratch(x.shape());
    let mut yb = Tensor::zeros(*x.shape());
    b.forward_into(&x, &mut yb, &mut scratch, &ctx);
    let mut db = Tensor::zeros(*x.shape());
    b.backward_into(&x, &dy, &mut db, &mut scratch, &ctx);

    assert_eq!(ya.as_slice(), yb.as_slice());
    assert_eq!(da.as_slice(), db.as_slice());
}
