//! The paper's quantitative claims, asserted against this
//! reproduction's measured (native) or simulated (device-model)
//! numbers. Each test cites the claim it checks. These are *shape*
//! assertions — who wins and by roughly what factor — not absolute
//! times (DESIGN.md §Hardware-Adaptation).

use cct::coordinator::scheduler;
use cct::device::profiles;
use cct::gemm::{sgemm, GemmDims, Trans};
use cct::lowering::{choose_lowering, optimizer, ConvShape, CostModel, LoweringType, MachineProfile};
use cct::net::presets;
use cct::rng::Pcg64;

/// §3.2: "CcT outperforms Caffe by 4.5×" (c4.4xlarge, CaffeNet, b=256):
/// simulated end-to-end with the Caffe strategy (per-image lowering)
/// vs the CcT strategy (whole-batch lowering) on the conv stack.
#[test]
fn claim_end_to_end_batching_speedup() {
    let dev = profiles::c4_4xlarge();
    let mut caffe = 0.0;
    let mut cct_t = 0.0;
    for (_, n, k, d, o) in presets::fig7_conv_geometry() {
        let shape = ConvShape { n, k, d, o, b: 256, pad: 0, stride: 1 };
        caffe += dev.conv_seconds_per_image(&shape, LoweringType::Type1);
        cct_t += dev.conv_seconds(&shape, LoweringType::Type1);
    }
    let speedup = caffe / cct_t;
    assert!(
        (3.0..10.0).contains(&speedup),
        "conv-stack batching speedup {speedup:.2}× (paper: 4.5× e2e, up to 10× on conv layers)"
    );
}

/// §3.2: "Caffe [GPU] is 1.86× faster than CcT running on 8 CPU cores,
/// and slightly slower than CcT running on 16 CPU cores" — the
/// FLOPS-proportionality claim across devices.
#[test]
fn claim_gpu_vs_cpu_proportional_to_flops() {
    let gpu = profiles::grid_k520();
    let cpu8 = profiles::c4_4xlarge();
    let cpu16 = profiles::c4_8xlarge();
    let mut t_gpu = 0.0;
    let mut t8 = 0.0;
    let mut t16 = 0.0;
    for (_, n, k, d, o) in presets::fig7_conv_geometry() {
        let shape = ConvShape { n, k, d, o, b: 256, pad: 0, stride: 1 };
        t_gpu += gpu.conv_seconds_with_transfer(&shape, LoweringType::Type1);
        t8 += cpu8.conv_seconds(&shape, LoweringType::Type1);
        t16 += cpu16.conv_seconds(&shape, LoweringType::Type1);
    }
    let ratio8 = t8 / t_gpu;
    assert!((1.3..2.6).contains(&ratio8), "GPU vs 8-core ratio {ratio8:.2} (paper: 1.86×)");
    assert!(t16 < t_gpu * 1.15, "16-core CPU should be ≈ or faster than the K520 (paper: slightly faster)");
}

/// Fig 4(a): hybrid CPU+GPU is ~1.2× over GPU-only on conv1, with the
/// GPU taking ~85% of the batch.
#[test]
fn claim_hybrid_conv1_speedup_and_share() {
    let gpu = profiles::grid_k520();
    let cpu = profiles::g2_host_cpu();
    let shape = ConvShape { n: 227, k: 11, d: 3, o: 96, b: 256, pad: 0, stride: 4 };
    let gpu_only = scheduler::simulate_hybrid_conv(&shape, &[gpu.clone()], &[256], LoweringType::Type1);
    let hybrid = scheduler::schedule_and_simulate(&shape, &[gpu, cpu], LoweringType::Type1);
    let speedup = gpu_only.makespan_s / hybrid.makespan_s;
    let gpu_share = hybrid.assignment[0] as f64 / 256.0;
    assert!((1.05..1.35).contains(&speedup), "hybrid speedup {speedup:.2} (paper: 1.20×)");
    assert!((0.80..0.95).contains(&gpu_share), "gpu share {gpu_share:.2} (paper: 0.85)");
}

/// Fig 5: on g2.8xlarge, 1 GPU + CPU > 1.15×; 4 GPUs > 3× (3.12×).
#[test]
fn claim_multi_gpu_scaling() {
    let gpu = profiles::grid_k520();
    let host = profiles::g2_8xlarge_cpu();
    let convs: Vec<ConvShape> = presets::fig7_conv_geometry()
        .into_iter()
        .map(|(_, n, k, d, o)| ConvShape { n, k, d, o, b: 256, pad: 0, stride: 1 })
        .collect();

    let time = |devices: &[cct::device::DeviceSpec]| -> f64 {
        convs
            .iter()
            .map(|s| scheduler::schedule_and_simulate(s, devices, LoweringType::Type1).makespan_s)
            .sum()
    };
    let one = time(&[gpu.clone()]);
    let one_plus_cpu = time(&[gpu.clone(), host.clone()]);
    let four = time(&[gpu.clone(), gpu.clone(), gpu.clone(), gpu.clone()]);
    let s1 = one / one_plus_cpu;
    let s4 = one / four;
    assert!(s1 > 1.12, "1 GPU + CPU speedup {s1:.2} (paper: 1.17×)");
    assert!(s4 > 3.0 && s4 <= 4.05, "4-GPU speedup {s4:.2} (paper: 3.12×)");
}

/// Appendix B / Fig 9: the FLOPS-proportional heuristic is within 5%
/// of the optimal split, and extreme splits are worse.
#[test]
fn claim_heuristic_near_optimal() {
    let gpu = profiles::grid_k520();
    let cpu = profiles::g2_host_cpu();
    for depth in [48usize, 96] {
        let shape = ConvShape { n: 227, k: 11, d: 3, o: depth, b: 256, pad: 0, stride: 4 };
        let heuristic = scheduler::schedule_and_simulate(&shape, &[gpu.clone(), cpu.clone()], LoweringType::Type1);
        let (p_opt, optimal) =
            scheduler::optimal_two_device_split(&shape, &[gpu.clone(), cpu.clone()], LoweringType::Type1);
        let gap = heuristic.makespan_s / optimal.makespan_s;
        assert!(gap < 1.05, "o={depth}: heuristic {gap:.3}× of optimal (claim: ≤1.05)");
        assert!((0.7..0.95).contains(&p_opt), "optimal GPU fraction {p_opt:.2} (paper: 0.83)");
    }
}

/// Appendix A / Fig 8(c): the optimal lowering flips from Type 1 to
/// Type 3 as d/o grows — *measured natively* on this machine.
#[test]
fn claim_lowering_crossover_measured() {
    use cct::bench_util::bench;
    use cct::lowering::conv_forward;
    use cct::tensor::Tensor;

    let measure = |d: usize, o: usize, ty: LoweringType| -> f64 {
        let shape = ConvShape::simple(13, 3, d, o, 4);
        let mut rng = Pcg64::new(17);
        let data = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
        let w = Tensor::randn(shape.weight_shape(), 0.0, 0.1, &mut rng);
        bench(1, 3, || {
            let _ = conv_forward(ty, &shape, &data, &w, 1);
        })
        .min
    };
    // d ≪ o: Type 1 must win. d ≫ o: Type 3 must win.
    let low_ratio_t1 = measure(16, 512, LoweringType::Type1);
    let low_ratio_t3 = measure(16, 512, LoweringType::Type3);
    assert!(
        low_ratio_t1 < low_ratio_t3,
        "at d/o=0.03, T1 ({low_ratio_t1:.4}s) must beat T3 ({low_ratio_t3:.4}s)"
    );
    let high_ratio_t1 = measure(1024, 8, LoweringType::Type1);
    let high_ratio_t3 = measure(1024, 8, LoweringType::Type3);
    assert!(
        high_ratio_t3 < high_ratio_t1,
        "at d/o=128, T3 ({high_ratio_t3:.4}s) must beat T1 ({high_ratio_t1:.4}s)"
    );
}

/// §3.2: "Both CcT and Caffe use only Lowering Type 1 … [Type 3 faster]
/// only true of conv5 and the difference is small" — the optimizer must
/// agree that Type 1 is (near-)optimal on every CaffeNet conv layer.
#[test]
fn claim_type1_near_optimal_on_caffenet() {
    let prof = MachineProfile::c4_4xlarge();
    for (name, n, k, d, o) in presets::fig7_conv_geometry() {
        let shape = ConvShape::simple(n, k, d, o, 256);
        let best = choose_lowering(&shape, &prof);
        let t_best = optimizer::estimate_seconds(&shape, best, &prof);
        let t1 = optimizer::estimate_seconds(&shape, LoweringType::Type1, &prof);
        assert!(
            t1 / t_best < 1.25,
            "{name}: Type 1 is {:.2}× of best {best} — paper says the difference is small",
            t1 / t_best
        );
    }
}

/// §1: "the optimal lowering contributes around 20% of the execution
/// time for a single layer" — cost model: lowering+lifting overhead of
/// Type 1 is a minor fraction of the GEMM on CaffeNet shapes.
#[test]
fn claim_lowering_overhead_minor() {
    for (_, n, k, d, o) in presets::fig7_conv_geometry().into_iter().skip(1) {
        let cm = CostModel::new(ConvShape::simple(n, k, d, o, 256));
        let c = cm.cost(LoweringType::Type1);
        // bytes moved by lower+lift vs GEMM FLOPs at ~10 FLOP/byte
        let overhead = (c.lower_writes + c.lift_ram_reads) as f64;
        let work = c.gemm_flops as f64;
        assert!(overhead * 10.0 < work, "lowering traffic dominates GEMM on n={n},k={k},d={d},o={o}");
    }
}

/// Fig 2(b)-adjacent, measured: on the *native* GEMM, a batched (tall)
/// lowered matrix sustains materially higher throughput than the b=1
/// slice of the same problem — the mechanism behind the 4.5×. On this
/// single-core box the penalty concentrates at genuinely thin outputs
/// (rows below the packing tile), e.g. a small-spatial conv per image;
/// the thread-level pathology of Fig 2(b) is covered by the device
/// model (see bench fig2_gemm_batching).
#[test]
fn claim_thin_gemm_slower_measured() {
    // small-spatial conv per-image GEMM: 4 output rows, k²d=2400, o=64.
    let cols = 2400usize;
    let o = 64usize;
    let rows1 = 4usize; // b = 1, tiny m²
    let rows16 = 4 * 16; // b = 16
    let mut rng = Pcg64::new(23);
    let mut a = vec![0f32; rows16 * cols];
    let mut b = vec![0f32; cols * o];
    rng.fill_uniform(&mut a, -1.0, 1.0);
    rng.fill_uniform(&mut b, -1.0, 1.0);
    let mut c = vec![0f32; rows16 * o];

    let time = |rows: usize, reps: usize, c: &mut [f32]| -> f64 {
        let dims = GemmDims { m: rows, n: o, k: cols };
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            sgemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, c, 1);
        }
        t0.elapsed().as_secs_f64() / reps as f64
    };
    // warmup
    time(rows1, 1, &mut c);
    let per_image_16 = time(rows1, 32, &mut c) * 16.0; // 16 thin GEMMs
    let batched_16 = time(rows16, 8, &mut c); // 1 fat GEMM
    let ratio = per_image_16 / batched_16;
    assert!(
        ratio > 1.05,
        "fat GEMM must beat 16 thin GEMMs (got ratio {ratio:.3})"
    );
}
