//! Substrate property tests + failure injection (coverage widening):
//! algebraic identities the compute substrates must satisfy, and
//! graceful-failure behaviour on malformed inputs.

use cct::gemm::{gemm_naive, sgemm, GemmDims, Trans};
use cct::lowering::{conv_forward, ConvShape, LoweringType};
use cct::net::{config::build_net, parse_net};
use cct::rng::Pcg64;
use cct::runtime::parse_manifest_line;
use cct::tensor::{read_tensor, write_tensor, Tensor};
use cct::testing::Prop;

// ---------------------------------------------------------------- GEMM

#[test]
fn gemm_linear_in_alpha() {
    Prop::new("sgemm is linear in alpha", 20).run(|g| {
        let (m, n, k) = (g.usize_in(1, 24), g.usize_in(1, 24), g.usize_in(1, 24));
        let a = g.vec_f32(m * k, -1.0, 1.0);
        let b = g.vec_f32(k * n, -1.0, 1.0);
        let alpha = g.f32_in(-2.0, 2.0);
        let dims = GemmDims { m, n, k };
        let mut c1 = vec![0f32; m * n];
        let mut c2 = vec![0f32; m * n];
        sgemm(Trans::N, Trans::N, dims, alpha, &a, &b, 0.0, &mut c1, 1);
        sgemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut c2, 1);
        for (x, y) in c1.iter().zip(c2.iter()) {
            assert!((x - alpha * y).abs() < 1e-3, "{x} vs α·{y}");
        }
    });
}

#[test]
fn gemm_transpose_identity() {
    // (A·B)ᵀ = Bᵀ·Aᵀ — exercised through the Trans flags.
    Prop::new("(AB)^T = B^T A^T", 15).run(|g| {
        let (m, n, k) = (g.usize_in(1, 16), g.usize_in(1, 16), g.usize_in(1, 16));
        let a = g.vec_f32(m * k, -1.0, 1.0);
        let b = g.vec_f32(k * n, -1.0, 1.0);
        let mut ab = vec![0f32; m * n];
        gemm_naive(Trans::N, Trans::N, GemmDims { m, n, k }, 1.0, &a, &b, 0.0, &mut ab);
        // Bᵀ·Aᵀ with row-major storage: use stored B as op(A)=Bᵀ (n×k),
        // stored A as op(B)=Aᵀ (k×m).
        let mut btat = vec![0f32; n * m];
        gemm_naive(Trans::T, Trans::T, GemmDims { m: n, n: m, k }, 1.0, &b, &a, 0.0, &mut btat);
        for i in 0..m {
            for j in 0..n {
                let x = ab[i * n + j];
                let y = btat[j * m + i];
                assert!((x - y).abs() < 1e-3, "({i},{j}): {x} vs {y}");
            }
        }
    });
}

#[test]
fn gemm_distributes_over_addition() {
    Prop::new("A(B+C) = AB + AC", 15).run(|g| {
        let (m, n, k) = (g.usize_in(1, 12), g.usize_in(1, 12), g.usize_in(1, 12));
        let a = g.vec_f32(m * k, -1.0, 1.0);
        let b = g.vec_f32(k * n, -1.0, 1.0);
        let c: Vec<f32> = g.vec_f32(k * n, -1.0, 1.0);
        let bc: Vec<f32> = b.iter().zip(&c).map(|(x, y)| x + y).collect();
        let dims = GemmDims { m, n, k };
        let mut lhs = vec![0f32; m * n];
        gemm_naive(Trans::N, Trans::N, dims, 1.0, &a, &bc, 0.0, &mut lhs);
        let mut rhs = vec![0f32; m * n];
        gemm_naive(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut rhs);
        gemm_naive(Trans::N, Trans::N, dims, 1.0, &a, &c, 1.0, &mut rhs);
        for (x, y) in lhs.iter().zip(rhs.iter()) {
            assert!((x - y).abs() < 1e-3);
        }
    });
}

// ---------------------------------------------------------------- conv

#[test]
fn conv_linear_in_input() {
    Prop::new("conv(x+y) = conv(x) + conv(y)", 10).run(|g| {
        let k = g.usize_in(1, 3);
        let n = k + g.usize_in(0, 4);
        let shape = ConvShape::simple(n, k, g.usize_in(1, 3), g.usize_in(1, 3), 1);
        let mut rng = Pcg64::new(g.usize_in(0, 1 << 20) as u64);
        let x = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
        let y = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
        let w = Tensor::randn(shape.weight_shape(), 0.0, 1.0, &mut rng);
        let mut xy = x.clone();
        xy.axpy(1.0, &y);
        let lhs = conv_forward(LoweringType::Type1, &shape, &xy, &w, 1);
        let mut rhs = conv_forward(LoweringType::Type1, &shape, &x, &w, 1);
        rhs.axpy(1.0, &conv_forward(LoweringType::Type1, &shape, &y, &w, 1));
        assert!(lhs.max_abs_diff(&rhs) < 1e-3);
    });
}

#[test]
fn conv_translation_equivariance() {
    // Shifting the input down-right by 1 shifts the (valid, stride-1)
    // output identically in its interior.
    let shape = ConvShape::simple(8, 3, 1, 1, 1);
    let mut rng = Pcg64::new(77);
    let x = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
    let w = Tensor::randn(shape.weight_shape(), 0.0, 1.0, &mut rng);
    let mut shifted = Tensor::zeros(shape.input_shape());
    for r in 1..8 {
        for c in 1..8 {
            shifted.set4(0, 0, r, c, x.at4(0, 0, r - 1, c - 1));
        }
    }
    let y = conv_forward(LoweringType::Type1, &shape, &x, &w, 1);
    let ys = conv_forward(LoweringType::Type1, &shape, &shifted, &w, 1);
    let m = shape.m();
    for r in 1..m {
        for c in 1..m {
            let a = y.at4(0, 0, r - 1, c - 1);
            let b = ys.at4(0, 0, r, c);
            assert!((a - b).abs() < 1e-4, "shift equivariance broken at ({r},{c})");
        }
    }
}

#[test]
fn conv_1x1_is_channel_matmul() {
    // A 1×1 convolution is a per-pixel channel mixing — check against
    // an explicit matmul.
    let shape = ConvShape::simple(5, 1, 3, 2, 2);
    let mut rng = Pcg64::new(78);
    let x = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
    let w = Tensor::randn(shape.weight_shape(), 0.0, 1.0, &mut rng);
    let y = conv_forward(LoweringType::Type2, &shape, &x, &w, 1);
    for bi in 0..2 {
        for j in 0..2 {
            for p in 0..25 {
                let mut want = 0f32;
                for i in 0..3 {
                    want += w.at4(j, i, 0, 0) * x.as_slice()[(bi * 3 + i) * 25 + p];
                }
                let got = y.as_slice()[(bi * 2 + j) * 25 + p];
                assert!((got - want).abs() < 1e-4);
            }
        }
    }
}

// ------------------------------------------------------ failure paths

#[test]
fn tensor_io_rejects_garbage() {
    // random bytes must never parse (or panic)
    Prop::new("tensor reader rejects noise", 20).run(|g| {
        let len = g.usize_in(0, 64);
        let noise: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
        assert!(read_tensor(&mut noise.as_slice()).is_err());
    });
}

#[test]
fn tensor_io_rejects_bit_flips_in_header() {
    let t = Tensor::arange((3, 4));
    let mut buf = Vec::new();
    write_tensor(&mut buf, &t).unwrap();
    // flip the rank field to an invalid value
    buf[4] = 200;
    assert!(read_tensor(&mut buf.as_slice()).is_err());
}

#[test]
fn net_parser_never_panics_on_noise() {
    Prop::new("prototxt-lite parser total on noise", 40).run(|g| {
        let len = g.usize_in(0, 80);
        let charset: Vec<char> = "abc{}:#\n 0123456789\"".chars().collect();
        let s: String = (0..len).map(|_| *g.choose(&charset)).collect();
        // must return Ok or Err — never panic
        let _ = parse_net(&s);
    });
}

#[test]
fn build_rejects_shape_underflow() {
    // a kernel larger than the running spatial size must fail cleanly
    let cfg = parse_net("input: 1 4 4\nconv { name: c out: 2 kernel: 9 }").unwrap();
    let mut rng = Pcg64::new(1);
    let r = std::panic::catch_unwind(move || build_net(&cfg, &mut rng));
    // either an Err or a descriptive panic from shape checking — but
    // never a silent success
    if let Ok(Ok(_)) = r {
        panic!("9×9 kernel on 4×4 input must not build");
    }
}

#[test]
fn manifest_rejects_malformed_lines() {
    assert!(parse_manifest_line("name args=x:f32 results=notanumber").is_err());
    assert!(parse_manifest_line("   ").is_err());
    assert!(parse_manifest_line("name args=a:f32").is_err());
    let ok = parse_manifest_line("n args=1:f32 results=2").unwrap();
    assert_eq!(ok.n_results, 2);
}

#[test]
fn checkpoint_blob_count_mismatch_rejected() {
    let cfg = parse_net("input: 1 6 6\nfc { name: f out: 2 std: 0.1 }").unwrap();
    let mut rng = Pcg64::new(2);
    let mut small = build_net(&cfg, &mut rng).unwrap();
    let cfg2 =
        parse_net("input: 1 6 6\nfc { name: f out: 2 std: 0.1 }\nfc { name: g out: 2 std: 0.1 }")
            .unwrap();
    let big = build_net(&cfg2, &mut rng).unwrap();
    let mut ckpt = Vec::new();
    big.save_params(&mut ckpt).unwrap();
    assert!(small.load_params(&mut ckpt.as_slice()).is_err());
}
