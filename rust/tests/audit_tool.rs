//! The audit audits itself: the checked-in tree must be clean, and the
//! cross-file claim-map check must see the real CI workflow and README.
//!
//! These are the same assertions CI's blocking `cargo run --bin
//! cct-audit` job makes; running them under `cargo test` means a
//! violation fails fast locally, with the same file:line report.

use cct::audit::{audit_tree, check_claim_map};
use std::path::Path;

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

/// The real source tree passes every audit check. If this fails, the
/// findings printed below are exactly what `cargo run --bin cct-audit`
/// would report — fix the code or annotate per the conventions in
/// `cct::audit`'s module docs.
#[test]
fn checked_in_tree_is_clean() {
    let findings = audit_tree(repo_root()).expect("audit walk failed");
    for f in &findings {
        eprintln!("{f}");
    }
    assert!(findings.is_empty(), "{} audit finding(s) — see stderr", findings.len());
}

/// Every `BENCH_*.json` artifact named in the CI workflow has a
/// claim-map row in the README (the audit's cross-file check, run
/// against the real files so drift fails a test, not just the binary).
#[test]
fn ci_bench_artifacts_have_readme_claim_rows() {
    let ci = std::fs::read_to_string(repo_root().join(".github/workflows/ci.yml"))
        .expect("CI workflow must exist");
    let readme =
        std::fs::read_to_string(repo_root().join("README.md")).expect("README must exist");
    let findings = check_claim_map(".github/workflows/ci.yml", &ci, &readme);
    for f in &findings {
        eprintln!("{f}");
    }
    assert!(findings.is_empty(), "CI bench artifacts missing README claim-map rows");
    // The check has teeth: it must actually be reading BENCH names out
    // of the workflow, not passing vacuously on an empty extraction.
    assert!(ci.contains("BENCH_"), "expected at least one BENCH_*.json artifact in CI");
}

/// A deliberately broken corpus produces findings with the right
/// check names — end-to-end through the same public API the binary
/// uses, complementing the per-check unit tests in `cct::audit`.
#[test]
fn violations_are_reported_by_check_name() {
    use cct::audit::SourceFile;
    let src = "\
fn f(p: *const u8, a: &std::sync::atomic::AtomicUsize) {
    let x = unsafe { *p };
    a.store(1, std::sync::atomic::Ordering::Relaxed);
}
";
    let file = SourceFile::parse("fixture.rs", src);
    let findings = cct::audit::audit_source(&file);
    let checks: Vec<&str> = findings.iter().map(|f| f.check).collect();
    assert!(checks.contains(&"safety"), "missing safety finding: {findings:?}");
    assert!(checks.contains(&"ordering"), "missing ordering finding: {findings:?}");
}
