//! Multi-tenant registry integration tests over the real HTTP
//! transport: `TcpStream` clients against
//! [`cct::serve::HttpServer::bind_registry`].
//!
//! Covers the `/v1/{model}` wire surface end to end (load, infer,
//! per-model stats, retire, validation failures, 405/Allow), and the
//! headline hot-swap guarantee: a client flood riding keep-alive
//! connections while the model is repeatedly hot-swapped sees *only*
//! clean outcomes — every response is a 200 (bit-stable within its
//! plan generation) or an honest backpressure shed with `Retry-After`.
//! Nothing is dropped, nothing is misrouted, and the steady-state
//! allocation counters stay at zero through every swap.

use cct::serve::registry::{LoadOptions, ModelRegistry, RegistryConfig};
use cct::serve::{HttpConfig, HttpServer, ServeConfig};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// `tiny` preset geometry: 3×16×16 input, 10 classes.
const SAMPLE_LEN: usize = 768;

fn registry(admission_capacity: usize) -> Arc<ModelRegistry> {
    Arc::new(
        ModelRegistry::new(RegistryConfig {
            serve: ServeConfig {
                workers: 2,
                max_batch: 4,
                max_wait_us: 200,
                ..Default::default()
            },
            admission_capacity,
        })
        .expect("registry config"),
    )
}

fn bind(reg: &Arc<ModelRegistry>) -> HttpServer {
    HttpServer::bind_registry(Arc::clone(reg), "127.0.0.1:0", HttpConfig::default())
        .expect("bind ephemeral port")
}

/// One parsed HTTP response, headers included.
struct Resp {
    status: u16,
    body: String,
    retry_after: Option<u64>,
    allow: Option<String>,
}

/// A keep-alive client that can issue arbitrary-method requests over
/// one connection.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(20)))
            .expect("client read timeout");
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().expect("clone stream");
        Client { reader: BufReader::new(stream), writer }
    }

    /// Issue `method path` with optional extra header lines (each
    /// `\r\n`-terminated) and a body, on the keep-alive connection.
    fn request(&mut self, method: &str, path: &str, extra: &str, body: &[u8]) -> Resp {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: cct\r\n{extra}Content-Length: {}\r\n\r\n",
            body.len()
        );
        self.writer.write_all(head.as_bytes()).expect("write head");
        self.writer.write_all(body).expect("write body");
        self.writer.flush().expect("flush request");
        self.read_response()
    }

    fn get(&mut self, path: &str) -> Resp {
        self.request("GET", path, "", b"")
    }

    fn read_response(&mut self) -> Resp {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("status line");
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("unparseable status line: {line:?}"));
        let mut len = 0usize;
        let mut retry_after = None;
        let mut allow = None;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).expect("header line");
            let t = h.trim_end();
            if t.is_empty() {
                break;
            }
            if let Some((k, v)) = t.split_once(':') {
                let (k, v) = (k.trim().to_ascii_lowercase(), v.trim());
                if k == "content-length" {
                    len = v.parse().expect("response content-length");
                } else if k == "retry-after" {
                    retry_after = Some(v.parse().expect("retry-after seconds"));
                } else if k == "allow" {
                    allow = Some(v.to_string());
                }
            }
        }
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).expect("response body");
        Resp { status, body: String::from_utf8_lossy(&body).into_owned(), retry_after, allow }
    }
}

fn json_sample(value: f32) -> Vec<u8> {
    let mut parts = Vec::with_capacity(SAMPLE_LEN);
    for _ in 0..SAMPLE_LEN {
        parts.push(format!("{value}"));
    }
    format!("[{}]", parts.join(",")).into_bytes()
}

/// Pull the integer after `"<key>":` out of a JSON body.
fn extract_u64(body: &str, key: &str) -> Option<u64> {
    body.split(&format!("\"{key}\":"))
        .nth(1)
        .and_then(|s| s.split([',', '}']).next())
        .and_then(|s| s.trim().parse().ok())
}

/// Pull the `"logits":[...]` array text out of a reply body.
fn extract_logits(body: &str) -> Option<String> {
    body.split("\"logits\":[")
        .nth(1)
        .and_then(|s| s.split(']').next())
        .map(|s| s.to_string())
}

#[test]
fn registry_http_api_round_trip() {
    let reg = registry(16);
    let server = bind(&reg);
    let addr = server.local_addr();
    let mut c = Client::connect(addr);

    // Empty registry: the legacy route has nowhere to go.
    let r = c.request("POST", "/infer", "", &json_sample(0.5));
    assert_eq!(r.status, 404, "body: {}", r.body);
    assert!(r.body.contains("no models loaded"), "{}", r.body);

    // Load two tenants over the wire: same architecture, different
    // seeds (= different weights), beta at twice the fair share.
    let r = c.request("PUT", "/v1/alpha", "X-Seed: 42\r\n", b"preset:tiny");
    assert_eq!(r.status, 200, "body: {}", r.body);
    assert!(r.body.contains("\"model\":\"alpha\""), "{}", r.body);
    assert!(r.body.contains("\"swapped\":false"), "{}", r.body);
    assert_eq!(extract_u64(&r.body, "generation"), Some(1), "{}", r.body);
    assert_eq!(extract_u64(&r.body, "sample_len"), Some(SAMPLE_LEN as u64), "{}", r.body);

    let r = c.request("PUT", "/v1/beta", "X-Seed: 7\r\nX-Weight: 2\r\n", b"preset:tiny");
    assert_eq!(r.status, 200, "body: {}", r.body);

    // Model-scoped inference tags each reply with its model and plan
    // generation; different seeds must answer differently.
    let ra = c.request("POST", "/v1/alpha/infer", "", &json_sample(0.5));
    assert_eq!(ra.status, 200, "body: {}", ra.body);
    assert!(ra.body.starts_with("{\"model\":\"alpha\",\"generation\":1,"), "{}", ra.body);
    let rb = c.request("POST", "/v1/beta/infer", "", &json_sample(0.5));
    assert_eq!(rb.status, 200, "body: {}", rb.body);
    assert_ne!(
        extract_logits(&ra.body),
        extract_logits(&rb.body),
        "different seeds must serve different weights"
    );

    // The legacy un-scoped route serves the default (first) model.
    let r = c.request("POST", "/infer", "", &json_sample(0.5));
    assert_eq!(r.status, 200, "body: {}", r.body);
    assert!(r.body.contains("\"model\":\"alpha\""), "{}", r.body);
    assert_eq!(extract_logits(&r.body), extract_logits(&ra.body), "default must route to alpha");

    // Per-model stats and the aggregate registry stats payload.
    let r = c.get("/v1/alpha");
    assert_eq!(r.status, 200, "body: {}", r.body);
    assert!(r.body.contains("\"completed\":2"), "{}", r.body);
    assert!(r.body.contains("\"weight\":1"), "{}", r.body);
    let r = c.get("/stats");
    assert_eq!(r.status, 200, "body: {}", r.body);
    assert!(r.body.contains("\"models\":{"), "{}", r.body);
    assert!(r.body.contains("\"alpha\":{"), "{}", r.body);
    assert!(r.body.contains("\"beta\":{"), "{}", r.body);
    assert!(r.body.contains("\"admission\":{\"capacity\":16}"), "{}", r.body);
    assert!(r.body.contains("\"http\":{"), "{}", r.body);

    // Wrong methods name what is allowed.
    let r = c.request("GET", "/v1/alpha/infer", "", b"");
    assert_eq!(r.status, 405, "body: {}", r.body);
    assert_eq!(r.allow.as_deref(), Some("POST"));
    let r = c.request("POST", "/v1/alpha", "", b"preset:tiny");
    assert_eq!(r.status, 405, "body: {}", r.body);
    assert_eq!(r.allow.as_deref(), Some("PUT, DELETE, GET"));

    // Validation failures are clean 4xx, never a wedged registry.
    let r = c.request("PUT", "/v1/gamma", "", b"preset:nope");
    assert_eq!(r.status, 400, "body: {}", r.body);
    let r = c.request("PUT", "/v1/gamma", "X-Seed: pi\r\n", b"preset:tiny");
    assert_eq!(r.status, 400, "body: {}", r.body);
    let r = c.request("PUT", "/v1/bad.name", "", b"preset:tiny");
    assert_eq!(r.status, 400, "body: {}", r.body);
    let r = c.request("PUT", "/v1/gamma", "", b"");
    assert_eq!(r.status, 400, "body: {}", r.body);
    let r = c.request("POST", "/v1/ghost/infer", "", &json_sample(0.5));
    assert_eq!(r.status, 404, "body: {}", r.body);

    // Retire beta: drained, reported, and gone from routing.
    let r = c.request("DELETE", "/v1/beta", "", b"");
    assert_eq!(r.status, 200, "body: {}", r.body);
    assert!(r.body.contains("\"retired\":true"), "{}", r.body);
    assert!(r.body.contains("\"completed\":1"), "{}", r.body);
    let r = c.request("POST", "/v1/beta/infer", "", &json_sample(0.5));
    assert_eq!(r.status, 404, "body: {}", r.body);
    let r = c.get("/v1/beta");
    assert_eq!(r.status, 404, "body: {}", r.body);
    let r = c.request("DELETE", "/v1/beta", "", b"");
    assert_eq!(r.status, 404, "body: {}", r.body);

    server.shutdown();
    let reports = reg.shutdown();
    assert_eq!(reports.len(), 1);
    assert_eq!(reports[0].0, "alpha");
    assert_eq!(reports[0].1.completed, 2);
    assert!(reports[0].1.worker_steady_allocs.iter().all(|&a| a == 0));
}

#[test]
fn hot_swap_under_load_drops_nothing() {
    // The tentpole guarantee: flood one model from keep-alive clients
    // while hot-swapping it repeatedly. Every response must be a 200
    // or an honest shed (429 + Retry-After) — never a drop, a 5xx, or
    // logits from the wrong plan generation.
    let reg = registry(32);
    let server = bind(&reg);
    let addr = server.local_addr();

    let seed0 = 100u64;
    reg.load(
        "m",
        &cct::serve::registry::preset_net("tiny").unwrap(),
        LoadOptions { weight: 1, seed: Some(seed0) },
    )
    .expect("initial load");

    const FLOODERS: usize = 3;
    const SWAPS: usize = 4;
    let flood_for = Duration::from_secs(2);

    let results: Vec<(u16, Option<u64>, Option<u64>, Option<String>)> =
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for _ in 0..FLOODERS {
                handles.push(scope.spawn(move || {
                    let mut c = Client::connect(addr);
                    let body = json_sample(0.5);
                    let mut out = Vec::new();
                    let t0 = Instant::now();
                    while t0.elapsed() < flood_for {
                        let r = c.request("POST", "/v1/m/infer", "", &body);
                        out.push((
                            r.status,
                            extract_u64(&r.body, "generation"),
                            r.retry_after,
                            extract_logits(&r.body),
                        ));
                    }
                    out
                }));
            }

            // Hot-swap the model under the flood: each PUT builds,
            // plans, and warms a new engine off the request path, then
            // flips it in and drains the old generation.
            let mut swapper = Client::connect(addr);
            for i in 0..SWAPS {
                std::thread::sleep(Duration::from_millis(150));
                let seed = seed0 + 1 + i as u64;
                let r = swapper.request(
                    "PUT",
                    "/v1/m",
                    &format!("X-Seed: {seed}\r\n"),
                    b"preset:tiny",
                );
                assert_eq!(r.status, 200, "swap {i} failed: {}", r.body);
                assert!(r.body.contains("\"swapped\":true"), "{}", r.body);
                assert_eq!(extract_u64(&r.body, "generation"), Some(2 + i as u64));
            }

            handles.into_iter().flat_map(|h| h.join().expect("flooder")).collect()
        });

    assert!(!results.is_empty());
    let oks = results.iter().filter(|r| r.0 == 200).count();
    assert!(oks > 0, "flood produced no successful replies");
    for (status, _, retry_after, _) in &results {
        assert!(
            *status == 200 || *status == 429,
            "hot swap must never drop or 5xx a request, got {status}"
        );
        if *status == 429 {
            assert!(retry_after.is_some(), "shed responses must carry Retry-After");
        }
    }

    // Within one plan generation, identical inputs produce identical
    // logits; across generations (different seeds) they differ. Either
    // violation would mean a request was misrouted mid-swap.
    let mut per_gen: HashMap<u64, String> = HashMap::new();
    for (status, generation, _, logits) in &results {
        if *status != 200 {
            continue;
        }
        let generation = generation.expect("200 replies carry a generation");
        let logits = logits.clone().expect("200 replies carry logits");
        match per_gen.get(&generation) {
            Some(seen) => assert_eq!(
                seen, &logits,
                "generation {generation} answered with two different logit vectors"
            ),
            None => {
                per_gen.insert(generation, logits);
            }
        }
    }
    assert!(
        per_gen.len() >= 2,
        "flood observed only generations {:?} across {SWAPS} swaps",
        per_gen.keys().collect::<Vec<_>>()
    );
    let distinct: std::collections::HashSet<&String> = per_gen.values().collect();
    assert_eq!(
        distinct.len(),
        per_gen.len(),
        "two generations with different seeds answered identically (misroute)"
    );

    server.shutdown();
    let reports = reg.shutdown();
    assert_eq!(reports.len(), 1);
    let (name, report) = &reports[0];
    assert_eq!(name, "m");
    assert_eq!(report.swaps, SWAPS as u64);
    assert_eq!(report.completed, oks as u64, "every 200 is a completion, nothing dropped");
    // Every generation's workers ran allocation-free after warmup —
    // (SWAPS + 1) generations × 2 workers each.
    assert_eq!(report.worker_steady_allocs.len(), (SWAPS + 1) * 2);
    assert!(
        report.worker_steady_allocs.iter().all(|&a| a == 0),
        "steady-state allocations during hot swaps: {:?}",
        report.worker_steady_allocs
    );
}
