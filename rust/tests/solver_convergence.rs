//! Seeded convergence regression for the synchronous solver path.
//!
//! Everything here is deterministic by construction (fixed seeds, fixed
//! data windows, fixed thread split), so the assertions are exact where
//! the math is exact and threshold-based only for the loss trajectory.
//! These pin down the baseline the async solver tests (`async_solver.rs`)
//! compare against: if sync convergence regresses, the async parity
//! numbers are meaningless.

use cct::coordinator::{partitioner, CnnCoordinator};
use cct::data::BlobCorpus;
use cct::net::config::parse_net;
use cct::rng::Pcg64;
use cct::solver::SolverConfig;
use cct::tensor::Tensor;

/// Small conv+fc net — big enough that the solver has real curvature to
/// descend, small enough that debug-profile CI can afford many steps.
const TINY: &str = r#"
name: tiny
input: 1 8 8
conv { name: c1 out: 4 kernel: 3 pad: 1 std: 0.1 }
relu { name: r1 }
fc   { name: f1 out: 3 std: 0.1 }
"#;

fn tiny_corpus(n: usize, seed: u64) -> (Tensor, Vec<usize>) {
    let mut rng = Pcg64::new(seed);
    let x = Tensor::randn((n, 1, 8, 8), 0.0, 1.0, &mut rng);
    let labels = (0..n).map(|i| i % 3).collect();
    (x, labels)
}

fn solver_cfg() -> SolverConfig {
    SolverConfig { base_lr: 0.05, momentum: 0.9, weight_decay: 0.0, ..Default::default() }
}

/// Run `rounds` coordinator steps over cycling corpus windows and
/// return the loss at every step.
fn run_sync(workers: usize, seed: u64, x: &Tensor, labels: &[usize], batch: usize, rounds: usize) -> Vec<f64> {
    let cfg = parse_net(TINY).unwrap();
    let mut coord = CnnCoordinator::new(&cfg, workers, workers, solver_cfg(), seed).unwrap();
    let n = labels.len();
    (0..rounds)
        .map(|r| {
            let s = partitioner::round_start(n, batch, r);
            coord.step(&x.slice_samples(s, s + batch), &labels[s..s + batch])
        })
        .collect()
}

#[test]
fn sync_solver_converges_from_fixed_seed() {
    // Regression anchor: with this exact (seed, net, data, lr) the loss
    // must drop well below its start within 30 steps. The 0.6 factor is
    // deliberately loose against the historical trajectory so only a
    // real optimizer regression trips it, not FP noise.
    let (x, labels) = tiny_corpus(24, 3);
    let losses = run_sync(2, 7, &x, &labels, 6, 30);
    assert!(losses.iter().all(|l| l.is_finite()), "non-finite loss in {losses:?}");
    let first = losses[0];
    let last = *losses.last().unwrap();
    assert!(last < first * 0.6, "sync solver stopped converging: {first:.4} → {last:.4}");
}

#[test]
fn sync_training_is_bitwise_deterministic() {
    // Two runs from the same seed must agree to the bit — the property
    // every S=0 async parity test builds on.
    let (x, labels) = tiny_corpus(18, 5);
    let a = run_sync(2, 11, &x, &labels, 6, 8);
    let b = run_sync(2, 11, &x, &labels, 6, 8);
    for (r, (la, lb)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(la.to_bits(), lb.to_bits(), "round {r}: {la} vs {lb}");
    }
}

#[test]
fn sync_final_weights_are_bitwise_deterministic() {
    let (x, labels) = tiny_corpus(18, 9);
    let cfg = parse_net(TINY).unwrap();
    let run = || {
        let mut coord = CnnCoordinator::new(&cfg, 2, 2, solver_cfg(), 13).unwrap();
        for r in 0..6 {
            let s = partitioner::round_start(18, 6, r);
            coord.step(&x.slice_samples(s, s + 6), &labels[s..s + 6]);
        }
        let mut bits = Vec::new();
        for p in coord.net().params() {
            bits.extend(p.data.as_slice().iter().map(|w| w.to_bits()));
        }
        bits
    };
    assert_eq!(run(), run(), "same seed produced different final weights");
}

#[test]
fn lenet_convergence_regression_under_coordinator() {
    // The realistic-scale anchor (satellite of the async work): LeNet on
    // a blob corpus through the coordinator, fixed seed, must reach a
    // clear fraction of its initial loss within 20 steps.
    let cfg = parse_net(cct::net::presets::LENET).unwrap();
    let solver = SolverConfig { base_lr: 0.05, momentum: 0.9, ..Default::default() };
    let mut coord = CnnCoordinator::new(&cfg, 2, 2, solver, 17).unwrap();
    let mut corpus = BlobCorpus::generate(1, 28, 10, 96, 0.2, 17);
    let mut losses = Vec::new();
    for _ in 0..20 {
        let (bx, by) = corpus.next_batch(12);
        losses.push(coord.step(&bx, &by));
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.8),
        "LeNet coordinator convergence regressed: {:.4} → {:.4}",
        losses[0],
        losses.last().unwrap()
    );
}
