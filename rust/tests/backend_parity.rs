//! `exec::Backend` parity guarantees (the refactor's safety net):
//!
//! 1. **CpuPoolBackend is the free-function path** — `sgemm` and the
//!    backend-routed Type-1 conv (forward and backward) are
//!    bit-identical to calling `gemm::sgemm` / `lowering::type1`
//!    directly, at every thread count and under contention from many
//!    OS threads sharing the one process pool.
//! 2. **SimBackend never touches the data** — latency injection and
//!    PCIe charges change *when*, never *what*: tensors are
//!    bit-identical to the host backend's, while `charged_seconds()`
//!    proves the cost model was consulted.
//! 3. **ExecCtx routing** — a whole net training step driven by
//!    `ExecCtx::on(<sim backend>)` computes exactly the numbers the
//!    default host context computes.

use cct::device::profiles;
use cct::exec::{cpu, Backend, SimBackend};
use cct::gemm::{sgemm, GemmDims, Trans};
use cct::layers::conv::ConvConfig;
use cct::layers::{ConvLayer, ExecCtx, FcLayer, Layer, PoolLayer, PoolMode, ReluLayer};
use cct::lowering::{type1, ConvShape};
use cct::net::Net;
use cct::rng::Pcg64;
use cct::tensor::Tensor;

fn rand_vec(n: usize, rng: &mut Pcg64) -> Vec<f32> {
    let mut v = vec![0f32; n];
    rng.fill_uniform(&mut v, -1.0, 1.0);
    v
}

/// Forward + backward conv through `backend`, from a fixed seed.
/// Returns (output, d_data, d_weights) for bitwise comparison.
fn conv_roundtrip_on(backend: &dyn Backend, shape: &ConvShape, threads: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::new(0xBAC0);
    let m = shape.m();
    let data = rand_vec(shape.b * shape.d * shape.n * shape.n, &mut rng);
    let weights = rand_vec(shape.o * type1::lowered_cols(shape), &mut rng);
    let d_out = rand_vec(shape.b * shape.o * m * m, &mut rng);
    let mut ws = type1::Workspace::new(shape);
    let mut out = vec![0f32; shape.b * shape.o * m * m];
    let mut d_data = vec![0f32; data.len()];
    let mut d_w = vec![0f32; weights.len()];
    type1::conv_type1_into_on(backend, shape, &data, &weights, threads, &mut ws, &mut out);
    type1::conv_type1_backward_into_on(
        backend,
        shape,
        &data,
        &weights,
        &d_out,
        threads,
        &mut ws,
        &mut d_data,
        &mut d_w,
    );
    (out, d_data, d_w)
}

/// Shapes chosen to cross pool tile boundaries: tall-skinny conv GEMMs
/// (the lowered form), a square-ish case, and prime-sized edges.
fn gemm_shapes() -> Vec<GemmDims> {
    vec![
        GemmDims { m: 257, n: 16, k: 72 },
        GemmDims { m: 1024, n: 32, k: 27 },
        GemmDims { m: 64, n: 64, k: 64 },
        GemmDims { m: 13, n: 7, k: 31 },
    ]
}

#[test]
fn cpu_backend_sgemm_is_bitwise_the_free_function() {
    let be = cpu();
    let mut rng = Pcg64::new(4242);
    for dims in gemm_shapes() {
        for &threads in &[1usize, 4] {
            let a = rand_vec(dims.m * dims.k, &mut rng);
            let b = rand_vec(dims.k * dims.n, &mut rng);
            let mut c0 = rand_vec(dims.m * dims.n, &mut rng);
            let mut c1 = c0.clone();
            sgemm(Trans::N, Trans::T, dims, 1.5, &a, &b, 0.25, &mut c0, threads);
            be.sgemm(Trans::N, Trans::T, dims, 1.5, &a, &b, 0.25, &mut c1, threads);
            assert_eq!(c0, c1, "m={} n={} k={} threads={threads}", dims.m, dims.n, dims.k);
        }
    }
}

#[test]
fn cpu_backend_conv_is_bitwise_the_raw_kernel_pipeline() {
    // Compose the pre-refactor pipeline by hand from the raw kernels
    // (im2col → GEMM → lift) and demand the backend-routed entry point
    // reproduces it bit for bit at every thread count.
    let shape = ConvShape { n: 12, k: 3, d: 3, o: 8, b: 5, pad: 1, stride: 1 };
    let rows = type1::lowered_rows(&shape);
    let cols = type1::lowered_cols(&shape);
    let m = shape.m();
    for &threads in &[1usize, 4] {
        let mut rng = Pcg64::new(0xBAC0);
        let data = rand_vec(shape.b * shape.d * shape.n * shape.n, &mut rng);
        let weights = rand_vec(shape.o * cols, &mut rng);
        let mut lowered = vec![0f32; rows * cols];
        type1::lower_batch_slice_threaded(&shape, &data, &mut lowered, threads);
        let mut r_hat = vec![0f32; rows * shape.o];
        let dims = GemmDims { m: rows, n: shape.o, k: cols };
        sgemm(Trans::N, Trans::T, dims, 1.0, &lowered, &weights, 0.0, &mut r_hat, threads);
        let mut want = vec![0f32; shape.b * shape.o * m * m];
        type1::lift_slice_threaded(&shape, &r_hat, &mut want, threads);

        let (got, _, _) = conv_roundtrip_on(cpu(), &shape, threads);
        assert_eq!(want, got, "backend conv diverged from raw kernels at threads={threads}");
    }
}

#[test]
fn cpu_backend_is_deterministic_under_contention() {
    // Many OS threads hammer the shared pool through the backend at
    // once; every one of them must still get the serial answer.
    let dims = GemmDims { m: 301, n: 24, k: 72 };
    let mut rng = Pcg64::new(9009);
    let a = rand_vec(dims.m * dims.k, &mut rng);
    let b = rand_vec(dims.k * dims.n, &mut rng);
    let mut want = vec![0f32; dims.m * dims.n];
    sgemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut want, 2);

    std::thread::scope(|scope| {
        for _ in 0..4 {
            let (a, b, want) = (&a, &b, &want);
            scope.spawn(move || {
                let be = cpu();
                for _ in 0..8 {
                    let mut c = vec![0f32; dims.m * dims.n];
                    be.sgemm(Trans::N, Trans::N, dims, 1.0, a, b, 0.0, &mut c, 2);
                    assert_eq!(&c, want, "contended backend GEMM diverged");
                }
            });
        }
    });
}

#[test]
fn sim_backend_changes_time_never_data() {
    let shape = ConvShape { n: 10, k: 3, d: 4, o: 6, b: 4, pad: 1, stride: 1 };
    let sim = SimBackend::new(profiles::grid_k520(), 0.0, 1);
    let (out_cpu, dd_cpu, dw_cpu) = conv_roundtrip_on(cpu(), &shape, 1);
    let (out_sim, dd_sim, dw_sim) = conv_roundtrip_on(&sim, &shape, 1);
    assert_eq!(out_cpu, out_sim, "sim forward must be bit-identical");
    assert_eq!(dd_cpu, dd_sim, "sim d_data must be bit-identical");
    assert_eq!(dw_cpu, dw_sim, "sim d_weights must be bit-identical");
    assert!(sim.charged_seconds() > 0.0, "sim must charge model time for the ops it ran");
}

/// A tiny conv→relu→pool→fc net for whole-step routing parity.
fn tiny_net(seed: u64) -> Net {
    let mut rng = Pcg64::new(seed);
    let conv = ConvLayer::new(
        "conv1",
        1,
        ConvConfig { out_channels: 4, kernel: 3, pad: 1, weight_std: 0.1, ..Default::default() },
        &mut rng,
    );
    let fc = FcLayer::new("fc", 4 * 4 * 4, 3, 0.1, &mut rng);
    let layers: Vec<Box<dyn Layer>> = vec![
        Box::new(conv),
        Box::new(ReluLayer::new("relu1")),
        Box::new(PoolLayer::new("pool1", PoolMode::Max, 2, 2, 0)),
        Box::new(fc),
    ];
    Net::new("tiny", (1, 8, 8), layers, vec![true, false, false, false])
}

#[test]
fn net_step_on_sim_backend_matches_default_ctx() {
    let mut rng = Pcg64::new(55);
    let x = Tensor::randn((2, 1, 8, 8), 0.0, 1.0, &mut rng);
    let labels = [0usize, 2];

    let mut net_host = tiny_net(42);
    let host_ctx = ExecCtx { seed: 11, ..Default::default() };
    let host_loss = net_host.forward_backward(&x, &labels, &host_ctx);

    let sim = SimBackend::new(profiles::c4_4xlarge(), 0.0, 1);
    let mut net_sim = tiny_net(42);
    let sim_ctx = ExecCtx { seed: 11, ..ExecCtx::on(&sim) };
    let sim_loss = net_sim.forward_backward(&x, &labels, &sim_ctx);

    assert_eq!(host_loss.to_bits(), sim_loss.to_bits(), "{host_loss} vs {sim_loss}");
    let mut host_params = net_host.params_mut();
    let mut sim_params = net_sim.params_mut();
    assert_eq!(host_params.len(), sim_params.len());
    for (hp, sp) in host_params.iter_mut().zip(sim_params.iter_mut()) {
        assert_eq!(hp.grad.as_slice(), sp.grad.as_slice(), "gradients diverge across backends");
    }
    assert!(sim.charged_seconds() > 0.0, "the sim backend should have been consulted");
}
