//! E-fig4: Fig 4 — (a) one conv layer and (b) end-to-end AlexNet,
//! speedups normalized to Caffe on the GPU instance, plus the §3.2
//! price analysis. Device-model simulation with the paper's published
//! peaks (DESIGN.md §Hardware-Adaptation).
//!
//! Run: `cargo bench --bench fig4_conv_hybrid`

use cct::bench_util::Table;
use cct::coordinator::scheduler;
use cct::device::{profiles, DeviceSpec};
use cct::lowering::{ConvShape, LoweringType};
use cct::net::presets;

/// End-to-end conv-stack time for a CPU device under a strategy.
fn e2e_cpu(dev: &DeviceSpec, per_image: bool) -> f64 {
    presets::fig7_conv_geometry()
        .into_iter()
        .map(|(_, n, k, d, o)| {
            let shape = ConvShape { n, k, d, o, b: 256, pad: 0, stride: 1 };
            if per_image {
                dev.conv_seconds_per_image(&shape, LoweringType::Type1)
            } else {
                dev.conv_seconds(&shape, LoweringType::Type1)
            }
        })
        .sum()
}

fn e2e_gpu(dev: &DeviceSpec) -> f64 {
    presets::fig7_conv_geometry()
        .into_iter()
        .map(|(_, n, k, d, o)| {
            let shape = ConvShape { n, k, d, o, b: 256, pad: 0, stride: 1 };
            dev.conv_seconds_with_transfer(&shape, LoweringType::Type1)
        })
        .sum()
}

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    let gpu = profiles::grid_k520();
    let g2cpu = profiles::g2_host_cpu();

    // ---- (a) one conv layer on g2.2xlarge ---------------------------
    let mut ta = Table::new(
        "Fig 4(a): conv1 speedups normalized to Caffe (GPU) — g2.2xlarge model",
        &["config", "depth 48", "depth 96", "paper 48", "paper 96"],
    );
    let paper = [
        ("Caffe (CPU)", 0.13, 0.11),
        ("CcT (CPU)", 0.44, 0.23),
        ("Caffe (GPU)", 1.00, 1.00),
        ("CcT (GPU)", 1.04, 1.04),
        ("CcT (CPU+GPU)", 1.20, 1.19),
    ];
    let mut ours: Vec<Vec<f64>> = vec![Vec::new(); 5];
    for depth in [48usize, 96] {
        let shape = ConvShape { n: 227, k: 11, d: 3, o: depth, b: 256, pad: 0, stride: 4 };
        let caffe_gpu = gpu.conv_seconds_with_transfer(&shape, LoweringType::Type1);
        // Caffe CPU: per-image lowering on the 4-core host.
        ours[0].push(caffe_gpu / g2cpu.conv_seconds_per_image(&shape, LoweringType::Type1));
        // CcT CPU: batched lowering on the host.
        ours[1].push(caffe_gpu / g2cpu.conv_seconds(&shape, LoweringType::Type1));
        ours[2].push(1.0);
        // CcT GPU: same strategy on the same device ⇒ parity.
        ours[3].push(1.0);
        // hybrid
        let hybrid = scheduler::schedule_and_simulate(&shape, &[gpu.clone(), g2cpu.clone()], LoweringType::Type1);
        ours[4].push(caffe_gpu / hybrid.makespan_s);
    }
    for (i, (name, p48, p96)) in paper.iter().enumerate() {
        ta.row(&[
            name.to_string(),
            format!("{:.2}×", ours[i][0]),
            format!("{:.2}×", ours[i][1]),
            format!("{p48:.2}×"),
            format!("{p96:.2}×"),
        ]);
    }
    ta.print();
    ta.write_csv("bench_out/fig4a.csv").ok();

    // ---- (b) end-to-end AlexNet across instances --------------------
    let caffe_gpu_e2e = e2e_gpu(&gpu);
    let mut tb = Table::new(
        "Fig 4(b): e2e AlexNet conv stack, normalized to Caffe (GPU on g2.2xlarge)",
        &["config", "instance", "ours", "paper"],
    );
    let c44 = profiles::c4_4xlarge();
    let c48 = profiles::c4_8xlarge();
    let rows = [
        ("Caffe (CPU)", &c44, true, 0.12),
        ("Caffe (CPU)", &c48, true, 0.16),
        ("CcT (CPU)", &c44, false, 0.53),
        ("CcT (CPU)", &c48, false, 1.02),
    ];
    for (name, dev, per_image, paper_x) in rows {
        let x = caffe_gpu_e2e / e2e_cpu(dev, per_image);
        tb.row(&[
            name.to_string(),
            dev.name.clone(),
            format!("{x:.2}×"),
            format!("{paper_x:.2}×"),
        ]);
    }
    tb.print();
    tb.write_csv("bench_out/fig4b.csv").ok();

    // ---- price analysis (§3.2) --------------------------------------
    // "running on a CPU instance is 2.6× more expensive than a GPU
    // instance for the same number of iterations."
    let price_gpu = 0.47; // $/h g2.2xlarge
    let price_cpu = 0.68; // $/h c4.4xlarge
    let t_cpu = e2e_cpu(&c44, false);
    let cost_ratio = (t_cpu * price_cpu) / (caffe_gpu_e2e * price_gpu);
    let mut tc = Table::new("Price analysis (§3.2)", &["metric", "ours", "paper"]);
    tc.row(&[
        "CcT-CPU(c4.4x) cost / Caffe-GPU(g2.2x) cost".into(),
        format!("{cost_ratio:.2}×"),
        "2.6×".into(),
    ]);
    tc.print();
    println!("\n(shape claim: CPU costs more, but ≪ the order of magnitude usually assumed)");
}
