//! E-fig6/E-fig7: the analytical tables.
//!
//! * Fig 6 — the lowering cost model, evaluated symbolically *and*
//!   cross-checked against the actual buffer sizes the lowering engine
//!   materializes (the model must describe the implementation).
//! * Fig 7 — CaffeNet conv geometry, regenerated from the net preset's
//!   shape walk (with the paper's conv4 d=256 typo noted).
//! * Autotuner calibration (PR 10) — runs `gemm::tune` over a conv
//!   shape sweep, tabulates the analytic prediction next to the
//!   measured time per lowering type, re-measures the tuned vs
//!   analytic-default GEMM strategy on the Fig 2 large-batch shape,
//!   asserts the post-tune hot path stays allocation-free, and writes
//!   `BENCH_autotune.json` for the CI perf-smoke gate.
//!
//! Run: `cargo bench --bench fig6_cost_model`
//! (set `CCT_BENCH_QUICK=1` for the CI-sized quick mode; honors
//! `CCT_TUNE_CACHE` for decision persistence)

use cct::bench_util::{bench, Table};
use cct::gemm::{pool, sgemm, tune, GemmDims, KernelChoice, Trans};
use cct::lowering::{
    choose_lowering, type1, type2, type3, ConvShape, CostModel, LoweringType, MachineProfile,
};
use cct::net::presets;
use cct::rng::Pcg64;
use cct::tensor::{alloc_stats, Tensor};

/// The Fig 2 large-batch conv2 GEMM (b=16 · 529 rows) the CI gate
/// compares tuned vs analytic-default strategies on.
const LARGE_DIMS: GemmDims = GemmDims { m: 8464, n: 256, k: 2400 };
const TUNE_THREADS: usize = 8;

fn kernel_label(k: KernelChoice) -> &'static str {
    match k {
        KernelChoice::Auto => "auto",
        KernelChoice::Avx512 => "avx512",
        KernelChoice::Portable => "portable",
    }
}

fn fmt_opt_ms(s: Option<f64>) -> String {
    match s {
        Some(v) => format!("{:.3}", v * 1e3),
        None => "-".into(),
    }
}

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    let quick = std::env::var("CCT_BENCH_QUICK").is_ok();

    // ---- Fig 6: cost model on conv2 (n=27, k=5, d=96, o=256, b=1) ---
    let shape = ConvShape::simple(27, 5, 96, 256, 1);
    let cm = CostModel::new(shape);
    let mut t = Table::new(
        "Fig 6: cost model (conv2 geometry, per image)",
        &["quantity", "Lowering 1", "Lowering 2", "Lowering 3"],
    );
    let cost: Vec<_> = LoweringType::ALL.iter().map(|&ty| cm.cost(ty)).collect();
    let fmt = |f: &dyn Fn(&cct::lowering::LoweringCost) -> u64| -> Vec<String> {
        cost.iter().map(|c| f(c).to_string()).collect()
    };
    for (name, vals) in [
        ("lowered data elems", fmt(&|c| c.lowered_data_elems)),
        ("lowered kernel elems", fmt(&|c| c.lowered_kernel_elems)),
        ("GEMM FLOPs", fmt(&|c| c.gemm_flops)),
        ("lift FLOPs", fmt(&|c| c.lift_flops)),
        ("lift RAM reads", fmt(&|c| c.lift_ram_reads)),
    ] {
        t.row(&[name.to_string(), vals[0].clone(), vals[1].clone(), vals[2].clone()]);
    }
    t.print();
    t.write_csv("bench_out/fig6.csv").ok();

    // Cross-check the model against the engine's real buffers.
    let mut rng = Pcg64::new(1);
    let data = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
    let w = Tensor::randn(shape.weight_shape(), 0.0, 0.1, &mut rng);
    {
        let rows = type1::lowered_rows(&shape);
        let cols = type1::lowered_cols(&shape);
        assert_eq!((rows * cols) as u64, cost[0].lowered_data_elems, "T1 size model mismatch");
        let (m, k, d, o) = (shape.m(), shape.k, shape.d, shape.o);
        // T2 D̂ is (n·m, k·d)
        let mut buf = vec![0f32; shape.n * m * k * d];
        type2::lower_batch(&shape, &data, &mut buf);
        assert_eq!(buf.len() as u64, cost[1].lowered_data_elems, "T2 size model mismatch");
        // T3 D̂ is (n², d)
        let mut buf3 = vec![0f32; shape.n * shape.n * d];
        type3::lower_batch(&shape, &data, &mut buf3);
        assert_eq!(buf3.len() as u64, cost[2].lowered_data_elems, "T3 size model mismatch");
        let _ = (o, w);
        println!("\ncross-check vs engine buffers: OK (all three lowered sizes match the model)");
    }

    // ---- Fig 7: CaffeNet conv geometry from the preset --------------
    let mut t7 = Table::new("Fig 7: CaffeNet conv layers", &["layer", "n", "k", "d", "o", "paper d"]);
    for (name, n, k, d, o) in presets::fig7_conv_geometry() {
        let paper_d = if name == "conv4" { "256 (typo)".to_string() } else { d.to_string() };
        t7.row(&[name.into(), n.to_string(), k.to_string(), d.to_string(), o.to_string(), paper_d]);
    }
    t7.print();
    t7.write_csv("bench_out/fig7.csv").ok();

    // ---- Autotuner: predicted vs measured calibration (PR 10) -------
    tune::set_mode(tune::TuneMode::On);
    pool::prewarm();
    let prof = MachineProfile::one_core();
    let ab = if quick { 2 } else { 8 }; // sweep batch size
    let sweep = [
        ConvShape::simple(27, 5, 96, 256, ab),  // conv2 (d < o)
        ConvShape::simple(13, 3, 64, 256, ab),  // d ≪ o: Type-1 country
        ConvShape::simple(13, 3, 256, 64, ab),  // d ≫ o: Type-3 country
        ConvShape::simple(13, 3, 384, 256, ab), // conv5-like crossover
    ];
    let mut tt = Table::new(
        &format!("Cost-model calibration: predicted vs measured per lowering (threads {TUNE_THREADS}, b={ab})"),
        &["shape (n,k,d,o)", "type", "predicted ms", "measured ms", "meas/pred", "analytic pick", "tuned pick"],
    );
    let mut shape_rows = Vec::new();
    for shape in &sweep {
        let tuned_pick = tune::tune_conv(shape, TUNE_THREADS);
        let analytic_pick = choose_lowering(shape, &prof);
        let cm6 = CostModel::new(*shape);
        let mut per_ty = Vec::new();
        for ty in LoweringType::ALL {
            let cal = cm6.calibrated(ty, &prof, TUNE_THREADS);
            tt.row(&[
                format!("({},{},{},{})", shape.n, shape.k, shape.d, shape.o),
                ty.to_string(),
                format!("{:.3}", cal.predicted_s * 1e3),
                fmt_opt_ms(cal.measured_s),
                cal.ratio().map_or("-".into(), |r| format!("{r:.2}")),
                analytic_pick.to_string(),
                tuned_pick.to_string(),
            ]);
            per_ty.push((ty, cal));
        }
        shape_rows.push((*shape, analytic_pick, tuned_pick, per_ty));
    }
    tt.print();
    tt.write_csv("bench_out/fig6_calibration.csv").ok();
    println!("measured column = autotuner wall clock (plan-time); '-' = type not measured.");

    // Tuned vs analytic-default strategy on the Fig 2 large-batch GEMM,
    // re-measured fresh through the public dispatch: CCT_TUNE=off
    // forces the analytic default path, on dispatches the cached
    // winner. Strict tie-breaking in the tuner means the winner never
    // measured slower, and this re-measurement checks it end to end.
    let d = tune::tune_gemm(LARGE_DIMS, TUNE_THREADS);
    let mut rng6 = Pcg64::new(606);
    let mut ga = vec![0f32; LARGE_DIMS.m * LARGE_DIMS.k];
    let mut gb = vec![0f32; LARGE_DIMS.k * LARGE_DIMS.n];
    rng6.fill_uniform(&mut ga, -1.0, 1.0);
    rng6.fill_uniform(&mut gb, -1.0, 1.0);
    let mut gc = vec![0f32; LARGE_DIMS.m * LARGE_DIMS.n];
    let (warm, iters) = if quick { (1, 2) } else { (1, 4) };
    let tuned_st = bench(warm, iters, || {
        sgemm(Trans::N, Trans::N, LARGE_DIMS, 1.0, &ga, &gb, 0.0, &mut gc, TUNE_THREADS);
    });
    tune::set_mode(tune::TuneMode::Off);
    let default_st = bench(warm, iters, || {
        sgemm(Trans::N, Trans::N, LARGE_DIMS, 1.0, &ga, &gb, 0.0, &mut gc, TUNE_THREADS);
    });
    tune::set_mode(tune::TuneMode::On);
    let speedup = default_st.min / tuned_st.min.max(1e-12);
    println!(
        "\nlarge-batch GEMM (m={}, k={}, n={}, threads {TUNE_THREADS}): tuned {:.2} ms vs default {:.2} ms ({speedup:.2}x); \
         winner mc={} kc={} nc={} kernel={} pool={}",
        LARGE_DIMS.m,
        LARGE_DIMS.k,
        LARGE_DIMS.n,
        tuned_st.min * 1e3,
        default_st.min * 1e3,
        d.strategy.bs.mc,
        d.strategy.bs.kc,
        d.strategy.bs.nc,
        kernel_label(d.strategy.kernel),
        d.strategy.use_pool,
    );
    println!(
        "CLAIM tuned dispatch ≥ analytic default (±5% timer noise): {}",
        if speedup >= 0.95 { "PASS" } else { "FAIL" }
    );

    // Post-tune steady state: dispatching tuned decisions must stay
    // allocation-free (the lookup is read-only; every tuned block size
    // fits the already-warm packing arenas).
    sgemm(Trans::N, Trans::N, LARGE_DIMS, 1.0, &ga, &gb, 0.0, &mut gc, TUNE_THREADS); // warm
    let arena_snap = pool::arena_allocs();
    let tensor_snap = alloc_stats::tensor_allocs();
    for _ in 0..3 {
        sgemm(Trans::N, Trans::N, LARGE_DIMS, 1.0, &ga, &gb, 0.0, &mut gc, TUNE_THREADS);
    }
    let arena_growth = pool::arena_allocs() - arena_snap;
    let tensor_allocs = alloc_stats::allocs_since(tensor_snap);
    println!(
        "CLAIM zero steady-state allocations under tuned dispatch: {} (arena growth {arena_growth}, tensor allocs {tensor_allocs})",
        if arena_growth == 0 && tensor_allocs == 0 { "PASS" } else { "FAIL" }
    );

    // Machine-readable artifact for the CI perf-smoke gate.
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"fig6_cost_model\",\n");
    out.push_str(&format!("  \"mode\": \"{}\",\n", if quick { "quick" } else { "full" }));
    out.push_str(&format!("  \"threads\": {TUNE_THREADS},\n"));
    out.push_str("  \"shapes\": [\n");
    for (i, (shape, analytic_pick, tuned_pick, per_ty)) in shape_rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"n\": {}, \"k\": {}, \"d\": {}, \"o\": {}, \"b\": {}, \"analytic\": \"{analytic_pick}\", \"tuned\": \"{tuned_pick}\", \"types\": [",
            shape.n, shape.k, shape.d, shape.o, shape.b
        ));
        for (j, (ty, cal)) in per_ty.iter().enumerate() {
            out.push_str(&format!(
                "{}{{\"ty\": \"{ty}\", \"predicted_s\": {:.9}, \"measured_s\": {}}}",
                if j == 0 { "" } else { ", " },
                cal.predicted_s,
                cal.measured_s.map_or("null".into(), |m| format!("{m:.9}")),
            ));
        }
        out.push_str(&format!("]}}{}\n", if i + 1 == shape_rows.len() { "" } else { "," }));
    }
    out.push_str("  ],\n");
    out.push_str(&format!(
        "  \"large_batch_gemm\": {{\"m\": {}, \"k\": {}, \"n\": {}, \"threads\": {TUNE_THREADS}, \"tuned_s\": {:.6}, \"default_s\": {:.6}, \"speedup\": {speedup:.4}, \"strategy\": {{\"mc\": {}, \"kc\": {}, \"nc\": {}, \"kernel\": \"{}\", \"pool\": {}}}}},\n",
        LARGE_DIMS.m,
        LARGE_DIMS.k,
        LARGE_DIMS.n,
        tuned_st.min,
        default_st.min,
        d.strategy.bs.mc,
        d.strategy.bs.kc,
        d.strategy.bs.nc,
        kernel_label(d.strategy.kernel),
        d.strategy.use_pool,
    ));
    out.push_str(&format!("  \"cache_gemm_entries\": {},\n", tune::cached_gemm_entries()));
    out.push_str(&format!("  \"cache_lowering_entries\": {},\n", tune::cached_lowering_entries()));
    out.push_str(&format!("  \"steady_arena_growth\": {arena_growth},\n"));
    out.push_str(&format!("  \"steady_tensor_allocs\": {tensor_allocs}\n"));
    out.push_str("}\n");
    std::fs::write("bench_out/BENCH_autotune.json", out).expect("writing BENCH_autotune.json");
    println!("wrote bench_out/BENCH_autotune.json");
}
