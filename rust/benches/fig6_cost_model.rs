//! E-fig6/E-fig7: the analytical tables.
//!
//! * Fig 6 — the lowering cost model, evaluated symbolically *and*
//!   cross-checked against the actual buffer sizes the lowering engine
//!   materializes (the model must describe the implementation).
//! * Fig 7 — CaffeNet conv geometry, regenerated from the net preset's
//!   shape walk (with the paper's conv4 d=256 typo noted).
//!
//! Run: `cargo bench --bench fig6_cost_model`

use cct::bench_util::Table;
use cct::lowering::{type1, type2, type3, ConvShape, CostModel, LoweringType};
use cct::net::presets;
use cct::rng::Pcg64;
use cct::tensor::Tensor;

fn main() {
    std::fs::create_dir_all("bench_out").ok();

    // ---- Fig 6: cost model on conv2 (n=27, k=5, d=96, o=256, b=1) ---
    let shape = ConvShape::simple(27, 5, 96, 256, 1);
    let cm = CostModel::new(shape);
    let mut t = Table::new(
        "Fig 6: cost model (conv2 geometry, per image)",
        &["quantity", "Lowering 1", "Lowering 2", "Lowering 3"],
    );
    let cost: Vec<_> = LoweringType::ALL.iter().map(|&ty| cm.cost(ty)).collect();
    let fmt = |f: &dyn Fn(&cct::lowering::LoweringCost) -> u64| -> Vec<String> {
        cost.iter().map(|c| f(c).to_string()).collect()
    };
    for (name, vals) in [
        ("lowered data elems", fmt(&|c| c.lowered_data_elems)),
        ("lowered kernel elems", fmt(&|c| c.lowered_kernel_elems)),
        ("GEMM FLOPs", fmt(&|c| c.gemm_flops)),
        ("lift FLOPs", fmt(&|c| c.lift_flops)),
        ("lift RAM reads", fmt(&|c| c.lift_ram_reads)),
    ] {
        t.row(&[name.to_string(), vals[0].clone(), vals[1].clone(), vals[2].clone()]);
    }
    t.print();
    t.write_csv("bench_out/fig6.csv").ok();

    // Cross-check the model against the engine's real buffers.
    let mut rng = Pcg64::new(1);
    let data = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
    let w = Tensor::randn(shape.weight_shape(), 0.0, 0.1, &mut rng);
    {
        let rows = type1::lowered_rows(&shape);
        let cols = type1::lowered_cols(&shape);
        assert_eq!((rows * cols) as u64, cost[0].lowered_data_elems, "T1 size model mismatch");
        let (m, k, d, o) = (shape.m(), shape.k, shape.d, shape.o);
        // T2 D̂ is (n·m, k·d)
        let mut buf = vec![0f32; shape.n * m * k * d];
        type2::lower_batch(&shape, &data, &mut buf);
        assert_eq!(buf.len() as u64, cost[1].lowered_data_elems, "T2 size model mismatch");
        // T3 D̂ is (n², d)
        let mut buf3 = vec![0f32; shape.n * shape.n * d];
        type3::lower_batch(&shape, &data, &mut buf3);
        assert_eq!(buf3.len() as u64, cost[2].lowered_data_elems, "T3 size model mismatch");
        let _ = (o, w);
        println!("\ncross-check vs engine buffers: OK (all three lowered sizes match the model)");
    }

    // ---- Fig 7: CaffeNet conv geometry from the preset --------------
    let mut t7 = Table::new("Fig 7: CaffeNet conv layers", &["layer", "n", "k", "d", "o", "paper d"]);
    for (name, n, k, d, o) in presets::fig7_conv_geometry() {
        let paper_d = if name == "conv4" { "256 (typo)".to_string() } else { d.to_string() };
        t7.row(&[name.into(), n.to_string(), k.to_string(), d.to_string(), o.to_string(), paper_d]);
    }
    t7.print();
    t7.write_csv("bench_out/fig7.csv").ok();
}
