//! E-fig8 / E-fusion: Fig 8 — empirical lowering tradeoffs, *measured
//! natively* on this machine (the shape effects are machine-local, so
//! no simulation is needed), plus the §2.1 fusion experiment.
//!
//! * (a) time vs input channels d (o fixed)
//! * (b) time vs output channels o (d fixed)
//! * (c) Type1/Type3 ratio vs d/o — the crossover
//! * fusion: materialized Type 1 vs fused lower+GEMM
//!
//! Run: `cargo bench --bench fig8_lowering`

use cct::bench_util::{bench, fmt_secs, Table};
use cct::lowering::{conv_forward, fused, ConvShape, LoweringType};
use cct::rng::Pcg64;
use cct::tensor::Tensor;

fn measure(shape: &ConvShape, ty: LoweringType) -> f64 {
    let mut rng = Pcg64::new(7);
    let data = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
    let w = Tensor::randn(shape.weight_shape(), 0.0, 0.1, &mut rng);
    bench(1, 3, || {
        let _ = conv_forward(ty, shape, &data, &w, 1);
    })
    .min
}

fn main() {
    std::fs::create_dir_all("bench_out").ok();

    // ---- (a) vary d, fixed o=64 (n=13, k=3, b=8) --------------------
    let mut ta = Table::new(
        "Fig 8(a) measured: time vs input channels d (o=64, n=13, k=3, b=8)",
        &["d", "type1", "type2", "type3", "best"],
    );
    for d in [16usize, 64, 256, 512, 1024] {
        let shape = ConvShape::simple(13, 3, d, 64, 8);
        let ts: Vec<f64> = LoweringType::ALL.iter().map(|&ty| measure(&shape, ty)).collect();
        let best = LoweringType::ALL[argmin(&ts)];
        ta.row(&[d.to_string(), fmt_secs(ts[0]), fmt_secs(ts[1]), fmt_secs(ts[2]), best.to_string()]);
    }
    ta.print();
    ta.write_csv("bench_out/fig8a.csv").ok();

    // ---- (b) vary o, fixed d=256 ------------------------------------
    let mut tb = Table::new(
        "Fig 8(b) measured: time vs output channels o (d=256, n=13, k=3, b=8)",
        &["o", "type1", "type2", "type3", "best"],
    );
    for o in [8usize, 32, 128, 384, 768] {
        let shape = ConvShape::simple(13, 3, 256, o, 8);
        let ts: Vec<f64> = LoweringType::ALL.iter().map(|&ty| measure(&shape, ty)).collect();
        let best = LoweringType::ALL[argmin(&ts)];
        tb.row(&[o.to_string(), fmt_secs(ts[0]), fmt_secs(ts[1]), fmt_secs(ts[2]), best.to_string()]);
    }
    tb.print();
    tb.write_csv("bench_out/fig8b.csv").ok();

    // ---- (c) ratio sweep at constant d·o ----------------------------
    let mut tc = Table::new(
        "Fig 8(c) measured: T1 vs T3 vs d/o ratio (d·o = 16384, n=13, k=3, b=8)",
        &["d/o", "type1", "type3", "t1/t3", "winner"],
    );
    for (d, o) in [(16usize, 1024usize), (64, 256), (128, 128), (256, 64), (1024, 16), (2048, 8)] {
        let shape = ConvShape::simple(13, 3, d, o, 8);
        let t1 = measure(&shape, LoweringType::Type1);
        let t3 = measure(&shape, LoweringType::Type3);
        tc.row(&[
            format!("{:.3}", d as f64 / o as f64),
            fmt_secs(t1),
            fmt_secs(t3),
            format!("{:.2}", t1 / t3),
            if t1 < t3 { "type1".into() } else { "type3".into() },
        ]);
    }
    tc.print();
    tc.write_csv("bench_out/fig8c.csv").ok();
    println!("paper Fig 8(c): crossover as the ratio grows; band up to ~10× at the extremes.");

    // ---- fusion (§2.1: "up to 60%") ----------------------------------
    let mut tf = Table::new(
        "Fusion (§2.1): materialized Type 1 vs fused lower+GEMM",
        &["shape", "materialized", "fused", "fused workspace vs D̂"],
    );
    for (n, k, d, o, b) in [(27usize, 5usize, 96usize, 128usize, 8usize), (13, 3, 256, 384, 8)] {
        let shape = ConvShape::simple(n, k, d, o, b);
        let mut rng = Pcg64::new(9);
        let data = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
        let w = Tensor::randn(shape.weight_shape(), 0.0, 0.1, &mut rng);
        let t_mat = bench(1, 3, || {
            let _ = conv_forward(LoweringType::Type1, &shape, &data, &w, 1);
        })
        .min;
        let t_fused = bench(1, 3, || {
            let _ = fused::conv_fused(&shape, &data, &w, 1);
        })
        .min;
        let ws_ratio = fused::fused_workspace_bytes(&shape) as f64
            / cct::lowering::type1::Workspace::new(&shape).bytes() as f64;
        tf.row(&[
            format!("n={n} k={k} d={d} o={o} b={b}"),
            fmt_secs(t_mat),
            fmt_secs(t_fused),
            format!("{:.1}%", ws_ratio * 100.0),
        ]);
    }
    tf.print();
    tf.write_csv("bench_out/fig8_fusion.csv").ok();
}

fn argmin(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}
