//! E-serve: dynamic micro-batching serving throughput.
//!
//! The paper's Fig 2 shows CNN throughput tracking delivered FLOPS
//! once batching amortizes lowering and per-call overhead. A server
//! sees that same curve as a **latency-vs-throughput tradeoff**: the
//! `max_batch` knob trades per-request wait (p95/p99 latency) for
//! amortization (requests/s). This bench sweeps `max_batch` under a
//! closed-loop load generator at a fixed worker count and reports both
//! sides, on two nets:
//!
//! * `tinyserve` — a very small net where the per-request dispatch
//!   overhead dominates; micro-batching must amortize it away
//!   (acceptance: ≥ 3× the batch-1 request throughput at the same
//!   worker count).
//! * `convserve` — a conv-heavier net where the GEMM-efficiency side
//!   of the curve shows as well.
//!
//! A third section drives an **overload QoS scenario**: a best-effort
//! flood (half of it carrying tight deadlines) against an interactive
//! trickle on one worker. Acceptance: expired requests are shed
//! *before* the forward pass (shed count > 0, batches only contain
//! live requests), and the interactive lane's p99 stays below the
//! best-effort p99.
//!
//! A fourth section measures the **HTTP transport** itself: the same
//! closed-loop load driven over real sockets, once reconnecting per
//! request (`Connection: close` — a TCP handshake per inference) and
//! once over persistent keep-alive connections on the same bounded
//! handler pool. Acceptance: keep-alive sustains higher request
//! throughput at equal worker count, with the reuse counter proving
//! the connections actually persisted.
//!
//! A fifth section drives a **multi-tenant overload** scenario on the
//! model registry: a hot tenant (weight 2) flooding its model against
//! a minority tenant (weight 1) running a closed-loop trickle, with
//! weighted fair admission ON (shared capacity, per-tenant floors)
//! versus OFF (capacity 0). Acceptance: the minority tenant is *never*
//! admission-shed (it stays under its guaranteed floor), the hot
//! tenant *is* shed once over its floor in fair mode, and the minority
//! p99 under fair admission stays bounded relative to the
//! unfair/free-for-all run. Emits
//! `bench_out/BENCH_serve_multitenant.json`.
//!
//! Also asserts the plan-once invariant end-to-end: every worker's
//! steady-state tensor-allocation count must be 0.
//!
//! Run: `cargo bench --bench serve_throughput`

use cct::bench_util::Table;
use cct::net::parse_net;
use cct::rng::Pcg64;
use cct::serve::registry::{LoadOptions, ModelRegistry, RegistryConfig};
use cct::serve::{
    closed_loop, HttpConfig, HttpServer, InferOptions, Lane, ServeConfig, ServeEngine,
    ServeReport, SubmitError,
};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const TINY: &str = "
name: tinyserve
input: 1 8 8
conv { name: c1 out: 4 kernel: 3 pad: 1 std: 0.1 }
relu { name: r1 }
pool { name: p1 mode: max kernel: 2 stride: 2 }
fc   { name: f1 out: 10 std: 0.1 }
";

const CONV: &str = "
name: convserve
input: 3 16 16
conv { name: conv1 out: 16 kernel: 3 pad: 1 std: 0.1 }
relu { name: relu1 }
pool { name: pool1 mode: max kernel: 2 stride: 2 }
conv { name: conv2 out: 16 kernel: 3 pad: 1 std: 0.1 }
relu { name: relu2 }
pool { name: pool2 mode: max kernel: 2 stride: 2 }
fc   { name: fc1 out: 10 std: 0.1 }
";

const WORKERS: usize = 2;
const CLIENTS: usize = 32;
const REQUESTS: usize = 2_000;

fn sweep(name: &str, cfg_text: &str) -> Vec<(usize, f64, ServeReport)> {
    let cfg = parse_net(cfg_text).expect("net parses");
    let mut t = Table::new(
        &format!(
            "Serving latency vs throughput: {name} ({WORKERS} workers, {CLIENTS} closed-loop clients, {REQUESTS} requests/config)"
        ),
        &["max_batch", "buckets", "req/s", "vs b=1", "mean batch", "p50 ms", "p95 ms", "p99 ms"],
    );
    let mut series: Vec<(usize, f64, ServeReport)> = Vec::new();
    for max_batch in [1usize, 2, 4, 8, 16, 32] {
        let config = ServeConfig {
            workers: WORKERS,
            max_batch,
            max_wait_us: if max_batch == 1 { 0 } else { 2_000 },
            queue_cap: 1024,
            ..Default::default()
        };
        // Warm the process (caches, allocator, code paths) on a
        // throwaway engine so the measured engine's report covers
        // exactly the measured load — no warmup samples in the
        // percentiles, same denominator for every column.
        {
            let warm = ServeEngine::start(&cfg, config.clone()).expect("warmup engine starts");
            let _ = closed_loop(&warm, 8, 200);
            warm.shutdown();
        }
        let engine = ServeEngine::start(&cfg, config).expect("engine starts");
        let buckets = engine
            .buckets()
            .iter()
            .map(|b| b.to_string())
            .collect::<Vec<_>>()
            .join("/");
        let wall = closed_loop(&engine, CLIENTS, REQUESTS);
        let report = engine.shutdown();
        let rate = REQUESTS as f64 / wall;
        let base = series.first().map(|&(_, r, _)| r).unwrap_or(rate);
        t.row(&[
            max_batch.to_string(),
            buckets,
            format!("{rate:.0}"),
            format!("{:.2}×", rate / base),
            format!("{:.2}", report.mean_batch),
            format!("{:.2}", report.latency.p50_us / 1e3),
            format!("{:.2}", report.latency.p95_us / 1e3),
            format!("{:.2}", report.latency.p99_us / 1e3),
        ]);
        series.push((max_batch, rate, report));
    }
    t.print();
    t.write_csv(&format!("bench_out/serve_throughput_{name}.csv")).ok();
    series
}

/// Overload QoS: one worker, a best-effort flood (every other client
/// with a tight deadline), an interactive trickle. Returns whether the
/// acceptance criteria held.
fn overload_qos() -> bool {
    let cfg = parse_net(CONV).expect("net parses");
    let engine = ServeEngine::start(
        &cfg,
        ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait_us: 2_000,
            queue_cap: 256,
            adaptive_wait: true,
            ..Default::default()
        },
    )
    .expect("engine starts");
    let len = engine.sample_len();

    const BE_CLIENTS: usize = 8;
    const BE_PER_CLIENT: usize = 300;
    const IA_CLIENTS: usize = 2;
    const IA_PER_CLIENT: usize = 60;

    std::thread::scope(|scope| {
        for c in 0..BE_CLIENTS {
            let handle = engine.handle();
            scope.spawn(move || {
                let mut rng = Pcg64::new(0xbe + c as u64);
                let mut sample = vec![0f32; len];
                rng.fill_uniform(&mut sample, -1.0, 1.0);
                // Even clients carry a deadline far tighter than the
                // backlog's queueing delay — their requests expire in
                // the queue and must be shed; odd clients ride the
                // backlog out and define the best-effort latency tail.
                let opts = if c % 2 == 0 {
                    InferOptions::best_effort().with_deadline_us(1_500)
                } else {
                    InferOptions::best_effort()
                };
                let mut pending = Vec::new();
                for _ in 0..BE_PER_CLIENT {
                    match handle.try_infer_with(&sample, opts) {
                        Ok(p) => pending.push(p),
                        Err(SubmitError::QueueFull) => {} // shed at the door
                        Err(_) => break,
                    }
                }
                for p in pending {
                    let _ = p.wait_outcome();
                }
            });
        }
        for c in 0..IA_CLIENTS {
            let handle = engine.handle();
            scope.spawn(move || {
                let mut rng = Pcg64::new(0x1a + c as u64);
                let mut sample = vec![0f32; len];
                rng.fill_uniform(&mut sample, -1.0, 1.0);
                for _ in 0..IA_PER_CLIENT {
                    let _ = handle.infer(&sample); // blocking, interactive lane
                }
            });
        }
    });
    let report = engine.shutdown();

    let ia = *report.lane(Lane::Interactive);
    let be = *report.lane(Lane::BestEffort);
    let mut t = Table::new(
        "Overload QoS: convserve, 1 worker, best-effort flood vs interactive trickle",
        &["lane", "completed", "p50 ms", "p99 ms", "max ms"],
    );
    for (name, lane) in [("interactive", &ia), ("best-effort", &be)] {
        t.row(&[
            name.to_string(),
            lane.completed.to_string(),
            format!("{:.2}", lane.latency.p50_us / 1e3),
            format!("{:.2}", lane.latency.p99_us / 1e3),
            format!("{:.2}", lane.latency.max_us / 1e3),
        ]);
    }
    t.print();
    println!(
        "sheds: {} expired (deadline) + {} rejected (backpressure); {} batches, mean batch {:.2}",
        report.expired, report.rejected, report.batches, report.mean_batch
    );

    let shed_ok = report.expired > 0;
    let prio_ok =
        ia.completed > 0 && be.completed > 0 && ia.latency.p99_us < be.latency.p99_us;
    println!(
        "acceptance: sheds before forward pass {} (expired {}), interactive p99 < best-effort p99 {} ({:.2} ms vs {:.2} ms)",
        if shed_ok { "PASS" } else { "FAIL" },
        report.expired,
        if prio_ok { "PASS" } else { "FAIL" },
        ia.latency.p99_us / 1e3,
        be.latency.p99_us / 1e3
    );
    let allocs_ok = report.worker_steady_allocs.iter().all(|&a| a == 0);
    if !allocs_ok {
        println!(
            "  REGRESSION: overload worker steady-state allocs {:?} (expected all 0)",
            report.worker_steady_allocs
        );
    }
    shed_ok && prio_ok && allocs_ok
}

/// Minimal HTTP/1.1 client for the transport scenario: POST one raw
/// f32 sample to `/infer` and parse the response by `Content-Length`
/// (required to speak keep-alive — read-to-end only works for
/// `Connection: close`).
struct HttpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl HttpClient {
    fn connect(addr: SocketAddr) -> HttpClient {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().expect("clone");
        HttpClient { reader: BufReader::new(stream), writer }
    }

    fn post_infer(&mut self, body: &[u8], close: bool) -> u16 {
        let conn = if close { "close" } else { "keep-alive" };
        self.writer
            .write_all(
                format!(
                    "POST /infer HTTP/1.1\r\nHost: cct\r\nConnection: {conn}\r\n\
                     Content-Type: application/octet-stream\r\nContent-Length: {}\r\n\r\n",
                    body.len()
                )
                .as_bytes(),
            )
            .expect("write head");
        self.writer.write_all(body).expect("write body");
        self.writer.flush().expect("flush");
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("status line");
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("bad status line {line:?}"));
        let mut len = 0usize;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).expect("header");
            let t = h.trim_end();
            if t.is_empty() {
                break;
            }
            if let Some((k, v)) = t.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    len = v.trim().parse().expect("content-length");
                }
            }
        }
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).expect("body");
        status
    }
}

/// Keep-alive vs reconnect-per-request over the real socket
/// transport, same engine and same bounded handler pool. Returns
/// whether keep-alive sustained more requests/s.
fn http_transport() -> bool {
    const HTTP_WORKERS: usize = 4;
    // One closed-loop client per handler slot: every connection keeps
    // its handler busy, and no keep-alive connection ever goes idle
    // while others wait (which would trigger the fairness yield and
    // close it mid-session).
    const CLIENTS: usize = HTTP_WORKERS;
    const PER_CLIENT: usize = 250;
    let cfg = parse_net(TINY).expect("net parses");
    let sample_len: usize = 64; // 1×8×8 flattened

    let mut t = Table::new(
        &format!(
            "HTTP transport: keep-alive vs reconnect-per-request (tinyserve, {WORKERS} engine workers, {HTTP_WORKERS} http handlers, {CLIENTS} clients × {PER_CLIENT} requests)"
        ),
        &["transport", "req/s", "connections", "reuses", "sheds", "p50 ms", "p99 ms"],
    );
    let mut rates = Vec::new();
    let mut reuses = Vec::new();
    for keep_alive in [false, true] {
        let engine = ServeEngine::start(
            &cfg,
            ServeConfig {
                workers: WORKERS,
                max_batch: 8,
                max_wait_us: 500,
                queue_cap: 1024,
                ..Default::default()
            },
        )
        .expect("engine starts");
        let server = HttpServer::bind_with(
            engine.handle(),
            "127.0.0.1:0",
            HttpConfig { workers: HTTP_WORKERS, ..Default::default() },
        )
        .expect("bind");
        let addr = server.local_addr();

        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..CLIENTS {
                scope.spawn(move || {
                    let mut rng = Pcg64::new(0x4717 + c as u64);
                    let mut sample = vec![0f32; sample_len];
                    rng.fill_uniform(&mut sample, -1.0, 1.0);
                    let mut body = Vec::with_capacity(sample_len * 4);
                    for v in &sample {
                        body.extend_from_slice(&v.to_le_bytes());
                    }
                    if keep_alive {
                        let mut client = HttpClient::connect(addr);
                        for _ in 0..PER_CLIENT {
                            assert_eq!(client.post_infer(&body, false), 200);
                        }
                    } else {
                        for _ in 0..PER_CLIENT {
                            let mut client = HttpClient::connect(addr);
                            assert_eq!(client.post_infer(&body, true), 200);
                        }
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        server.shutdown();
        let report = engine.shutdown();
        let rate = (CLIENTS * PER_CLIENT) as f64 / wall;
        rates.push(rate);
        reuses.push(report.http.keepalive_reuses);
        t.row(&[
            if keep_alive { "keep-alive" } else { "reconnect" }.to_string(),
            format!("{rate:.0}"),
            report.http.connections.to_string(),
            report.http.keepalive_reuses.to_string(),
            report.http.accept_sheds.to_string(),
            format!("{:.2}", report.latency.p50_us / 1e3),
            format!("{:.2}", report.latency.p99_us / 1e3),
        ]);
    }
    t.print();
    t.write_csv("bench_out/serve_throughput_http_transport.csv").ok();
    let faster = rates[1] > rates[0];
    let reused = reuses[1] > 0 && reuses[0] == 0;
    println!(
        "keep-alive vs reconnect at equal worker count: {:.2}× ({:.0} vs {:.0} req/s), {} reuses — {}",
        rates[1] / rates[0].max(1e-12),
        rates[1],
        rates[0],
        reuses[1],
        if faster && reused { "PASS" } else { "FAIL" }
    );
    faster && reused
}

/// Shared-GEMM-pool serving (PR 5): engine workers with a per-call
/// GEMM thread budget > 1 submit to the ONE process-wide compute pool
/// (queueing for it) instead of each spawning a private thread set per
/// call. Acceptance: every request completes and the serve loop stays
/// allocation-free with pooled GEMMs underneath.
fn shared_pool_serving() -> bool {
    let cfg = parse_net(CONV).expect("net parses");
    let engine = ServeEngine::start(
        &cfg,
        ServeConfig {
            workers: WORKERS,
            threads_per_worker: 2,
            max_batch: 8,
            max_wait_us: 1_000,
            queue_cap: 1024,
            ..Default::default()
        },
    )
    .expect("engine starts");
    const TOTAL: usize = 256;
    let wall = closed_loop(&engine, 8, TOTAL);
    let report = engine.shutdown();
    let done_ok = report.completed == TOTAL as u64;
    let allocs_ok = report.worker_steady_allocs.iter().all(|&a| a == 0);
    println!(
        "shared-pool serving: {WORKERS} workers × 2 GEMM threads on one compute pool ({} pool workers): {:.0} req/s, completed {}, steady allocs {:?} — {}",
        cct::gemm::pool::global_workers(),
        TOTAL as f64 / wall,
        report.completed,
        report.worker_steady_allocs,
        if done_ok && allocs_ok { "PASS" } else { "FAIL" }
    );
    done_ok && allocs_ok
}

/// One multi-tenant overload run on the registry: `hot` (weight 2)
/// flooded by window-limited async clients, `minority` (weight 1)
/// served closed-loop, both models the same conv net on their own
/// single-worker engines over the shared GEMM pool.
struct TenantOutcome {
    minority_p99_us: f64,
    minority_completed: u64,
    minority_sheds: u64,
    hot_completed: u64,
    hot_sheds: u64,
}

fn multi_tenant_run(admission_capacity: usize) -> TenantOutcome {
    const MIN_CLIENTS: usize = 2;
    const MIN_PER_CLIENT: usize = 100;
    const HOT_CLIENTS: usize = 6;
    /// Async in-flight window per flood client — far above any fair
    /// floor, so the flood always presses against admission.
    const HOT_WINDOW: usize = 16;

    let cfg = parse_net(CONV).expect("net parses");
    let reg = Arc::new(
        ModelRegistry::new(RegistryConfig {
            serve: ServeConfig {
                workers: 1,
                max_batch: 8,
                max_wait_us: 1_000,
                queue_cap: 256,
                ..Default::default()
            },
            admission_capacity,
        })
        .expect("registry config"),
    );
    let sw = reg.load("hot", &cfg, LoadOptions { weight: 2, seed: Some(1) }).expect("load hot");
    reg.load("minority", &cfg, LoadOptions { weight: 1, seed: Some(2) }).expect("load minority");
    let len = sw.sample_len;

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let stop = &stop;
        for c in 0..HOT_CLIENTS {
            let reg = Arc::clone(&reg);
            scope.spawn(move || {
                let mut rng = Pcg64::new(0x407 + c as u64);
                let mut sample = vec![0f32; len];
                rng.fill_uniform(&mut sample, -1.0, 1.0);
                let mut pending = VecDeque::new();
                while !stop.load(Ordering::Relaxed) {
                    match reg.submit("hot", &sample, InferOptions::best_effort()) {
                        Ok(sub) => pending.push_back(sub),
                        // Shed (or lane full): reap one in-flight
                        // reply, then press on — a flooder that never
                        // backs off further than admission forces it.
                        Err(_) => match pending.pop_front() {
                            Some(p) => {
                                let _ = p.wait_outcome();
                            }
                            None => std::thread::sleep(Duration::from_micros(200)),
                        },
                    }
                    if pending.len() >= HOT_WINDOW {
                        if let Some(p) = pending.pop_front() {
                            let _ = p.wait_outcome();
                        }
                    }
                }
                for p in pending {
                    let _ = p.wait_outcome();
                }
            });
        }
        let minority: Vec<_> = (0..MIN_CLIENTS)
            .map(|c| {
                let reg = Arc::clone(&reg);
                scope.spawn(move || {
                    let mut rng = Pcg64::new(0x317 + c as u64);
                    let mut sample = vec![0f32; len];
                    rng.fill_uniform(&mut sample, -1.0, 1.0);
                    for _ in 0..MIN_PER_CLIENT {
                        let _ = reg.infer("minority", &sample, InferOptions::default());
                    }
                })
            })
            .collect();
        // The flood runs for exactly as long as the minority tenant
        // has work — its whole run happens under contention.
        for h in minority {
            h.join().expect("minority client");
        }
        stop.store(true, Ordering::Relaxed);
    });

    let reports = reg.shutdown();
    let report = |name: &str| {
        reports
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, r)| r.clone())
            .expect("tenant report")
    };
    let (hot, minority) = (report("hot"), report("minority"));
    assert!(
        hot.worker_steady_allocs.iter().chain(&minority.worker_steady_allocs).all(|&a| a == 0),
        "steady-state allocs under multi-tenant load: hot {:?}, minority {:?}",
        hot.worker_steady_allocs,
        minority.worker_steady_allocs
    );
    TenantOutcome {
        minority_p99_us: minority.lane(Lane::Interactive).latency.p99_us,
        minority_completed: minority.completed,
        minority_sheds: minority.admission_sheds,
        hot_completed: hot.completed,
        hot_sheds: hot.admission_sheds,
    }
}

/// Weighted fair admission A/B: the same hot-flood-vs-minority load
/// with admission OFF (capacity 0, free-for-all) and ON (shared
/// capacity 12 at weights 2:1 → floors 8/4). Returns whether the
/// fairness acceptance held, and writes
/// `bench_out/BENCH_serve_multitenant.json`.
fn multi_tenant_fairness() -> bool {
    const CAPACITY: usize = 12;
    let unfair = multi_tenant_run(0);
    let fair = multi_tenant_run(CAPACITY);

    let mut t = Table::new(
        &format!(
            "Multi-tenant overload: hot flood (weight 2) vs minority trickle (weight 1), admission off vs capacity {CAPACITY}"
        ),
        &["admission", "minority p99 ms", "minority done", "minority sheds", "hot done", "hot sheds"],
    );
    for (name, o) in [("off", &unfair), ("fair", &fair)] {
        t.row(&[
            name.to_string(),
            format!("{:.2}", o.minority_p99_us / 1e3),
            o.minority_completed.to_string(),
            o.minority_sheds.to_string(),
            o.hot_completed.to_string(),
            o.hot_sheds.to_string(),
        ]);
    }
    t.print();

    // Acceptance: the floor guarantee protects the minority (never
    // shed, all requests answered), the flood is actually pressed back
    // (hot sheds in fair mode), and fair admission does not cost the
    // minority its tail (small multiplicative + absolute slack for
    // scheduler noise at sub-ms latencies).
    let minority_served = fair.minority_completed == unfair.minority_completed
        && fair.minority_completed > 0;
    let minority_never_shed = fair.minority_sheds == 0 && unfair.minority_sheds == 0;
    let hot_pressed_back = fair.hot_sheds > 0 && unfair.hot_sheds == 0;
    let p99_bounded = fair.minority_p99_us <= unfair.minority_p99_us * 1.25 + 2_000.0;
    println!(
        "acceptance: minority fully served {} ({} reqs), minority never shed {} (0 sheds), hot pressed back in fair mode {} ({} sheds), minority p99 bounded {} ({:.2} ms fair vs {:.2} ms off)",
        if minority_served { "PASS" } else { "FAIL" },
        fair.minority_completed,
        if minority_never_shed { "PASS" } else { "FAIL" },
        if hot_pressed_back { "PASS" } else { "FAIL" },
        fair.hot_sheds,
        if p99_bounded { "PASS" } else { "FAIL" },
        fair.minority_p99_us / 1e3,
        unfair.minority_p99_us / 1e3
    );

    let pass = minority_served && minority_never_shed && hot_pressed_back && p99_bounded;
    let json = format!(
        "{{\n  \"bench\": \"serve_multitenant\",\n  \"tenants\": {{\"hot\": {{\"weight\": 2}}, \"minority\": {{\"weight\": 1}}}},\n  \"admission_capacity\": {CAPACITY},\n  \"off\": {{\"minority_p99_ms\": {:.3}, \"minority_completed\": {}, \"minority_admission_sheds\": {}, \"hot_completed\": {}, \"hot_admission_sheds\": {}}},\n  \"fair\": {{\"minority_p99_ms\": {:.3}, \"minority_completed\": {}, \"minority_admission_sheds\": {}, \"hot_completed\": {}, \"hot_admission_sheds\": {}}},\n  \"acceptance\": {{\"minority_fully_served\": {minority_served}, \"minority_never_shed\": {minority_never_shed}, \"hot_pressed_back\": {hot_pressed_back}, \"minority_p99_bounded\": {p99_bounded}, \"pass\": {pass}}}\n}}\n",
        unfair.minority_p99_us / 1e3,
        unfair.minority_completed,
        unfair.minority_sheds,
        unfair.hot_completed,
        unfair.hot_sheds,
        fair.minority_p99_us / 1e3,
        fair.minority_completed,
        fair.minority_sheds,
        fair.hot_completed,
        fair.hot_sheds,
    );
    std::fs::write("bench_out/BENCH_serve_multitenant.json", json).ok();
    pass
}

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    let mut all_zero_allocs = true;
    for (name, cfg) in [("tinyserve", TINY), ("convserve", CONV)] {
        let series = sweep(name, cfg);
        let base = series[0].1;
        let (best_b, best_rate) = series
            .iter()
            .map(|&(b, r, _)| (b, r))
            .fold((1, 0.0), |acc, x| if x.1 > acc.1 { x } else { acc });
        println!(
            "{name}: best micro-batched throughput {best_rate:.0} req/s at max_batch={best_b} — {:.2}× batch-1 ({base:.0} req/s) at the same {WORKERS} workers (acceptance: ≥3×)",
            best_rate / base
        );
        for (b, _, report) in &series {
            if report.worker_steady_allocs.iter().any(|&a| a != 0) {
                all_zero_allocs = false;
                println!(
                    "  REGRESSION: max_batch={b} worker steady-state allocs {:?} (expected all 0)",
                    report.worker_steady_allocs
                );
            }
        }
    }
    println!(
        "steady-state serve-loop tensor allocations: {}",
        if all_zero_allocs { "0 across every config (plan-once holds)" } else { "NONZERO — see above" }
    );
    println!();
    let qos_ok = overload_qos();
    println!(
        "overload QoS acceptance: {}",
        if qos_ok { "PASS (sheds before FLOPs, interactive p99 bounded)" } else { "FAIL — see above" }
    );
    println!();
    let transport_ok = http_transport();
    println!(
        "keep-alive transport acceptance: {}",
        if transport_ok {
            "PASS (persistent connections out-serve reconnect-per-request)"
        } else {
            "FAIL — see above"
        }
    );
    println!();
    let pool_ok = shared_pool_serving();
    println!(
        "shared-pool serving acceptance: {}",
        if pool_ok {
            "PASS (workers share one compute pool, zero steady-state allocs)"
        } else {
            "FAIL — see above"
        }
    );
    println!();
    let fair_ok = multi_tenant_fairness();
    println!(
        "multi-tenant fair-admission acceptance: {}",
        if fair_ok {
            "PASS (minority floor held under hot-tenant flood, p99 bounded)"
        } else {
            "FAIL — see above"
        }
    );
}
