//! E-serve: dynamic micro-batching serving throughput.
//!
//! The paper's Fig 2 shows CNN throughput tracking delivered FLOPS
//! once batching amortizes lowering and per-call overhead. A server
//! sees that same curve as a **latency-vs-throughput tradeoff**: the
//! `max_batch` knob trades per-request wait (p95/p99 latency) for
//! amortization (requests/s). This bench sweeps `max_batch` under a
//! closed-loop load generator at a fixed worker count and reports both
//! sides, on two nets:
//!
//! * `tinyserve` — a very small net where the per-request dispatch
//!   overhead dominates; micro-batching must amortize it away
//!   (acceptance: ≥ 3× the batch-1 request throughput at the same
//!   worker count).
//! * `convserve` — a conv-heavier net where the GEMM-efficiency side
//!   of the curve shows as well.
//!
//! A third section drives an **overload QoS scenario**: a best-effort
//! flood (half of it carrying tight deadlines) against an interactive
//! trickle on one worker. Acceptance: expired requests are shed
//! *before* the forward pass (shed count > 0, batches only contain
//! live requests), and the interactive lane's p99 stays below the
//! best-effort p99.
//!
//! Also asserts the plan-once invariant end-to-end: every worker's
//! steady-state tensor-allocation count must be 0.
//!
//! Run: `cargo bench --bench serve_throughput`

use cct::bench_util::Table;
use cct::net::parse_net;
use cct::rng::Pcg64;
use cct::serve::{
    closed_loop, InferOptions, Lane, ServeConfig, ServeEngine, ServeReport, SubmitError,
};

const TINY: &str = "
name: tinyserve
input: 1 8 8
conv { name: c1 out: 4 kernel: 3 pad: 1 std: 0.1 }
relu { name: r1 }
pool { name: p1 mode: max kernel: 2 stride: 2 }
fc   { name: f1 out: 10 std: 0.1 }
";

const CONV: &str = "
name: convserve
input: 3 16 16
conv { name: conv1 out: 16 kernel: 3 pad: 1 std: 0.1 }
relu { name: relu1 }
pool { name: pool1 mode: max kernel: 2 stride: 2 }
conv { name: conv2 out: 16 kernel: 3 pad: 1 std: 0.1 }
relu { name: relu2 }
pool { name: pool2 mode: max kernel: 2 stride: 2 }
fc   { name: fc1 out: 10 std: 0.1 }
";

const WORKERS: usize = 2;
const CLIENTS: usize = 32;
const REQUESTS: usize = 2_000;

fn sweep(name: &str, cfg_text: &str) -> Vec<(usize, f64, ServeReport)> {
    let cfg = parse_net(cfg_text).expect("net parses");
    let mut t = Table::new(
        &format!(
            "Serving latency vs throughput: {name} ({WORKERS} workers, {CLIENTS} closed-loop clients, {REQUESTS} requests/config)"
        ),
        &["max_batch", "buckets", "req/s", "vs b=1", "mean batch", "p50 ms", "p95 ms", "p99 ms"],
    );
    let mut series: Vec<(usize, f64, ServeReport)> = Vec::new();
    for max_batch in [1usize, 2, 4, 8, 16, 32] {
        let config = ServeConfig {
            workers: WORKERS,
            max_batch,
            max_wait_us: if max_batch == 1 { 0 } else { 2_000 },
            queue_cap: 1024,
            ..Default::default()
        };
        // Warm the process (caches, allocator, code paths) on a
        // throwaway engine so the measured engine's report covers
        // exactly the measured load — no warmup samples in the
        // percentiles, same denominator for every column.
        {
            let warm = ServeEngine::start(&cfg, config.clone()).expect("warmup engine starts");
            let _ = closed_loop(&warm, 8, 200);
            warm.shutdown();
        }
        let engine = ServeEngine::start(&cfg, config).expect("engine starts");
        let buckets = engine
            .buckets()
            .iter()
            .map(|b| b.to_string())
            .collect::<Vec<_>>()
            .join("/");
        let wall = closed_loop(&engine, CLIENTS, REQUESTS);
        let report = engine.shutdown();
        let rate = REQUESTS as f64 / wall;
        let base = series.first().map(|&(_, r, _)| r).unwrap_or(rate);
        t.row(&[
            max_batch.to_string(),
            buckets,
            format!("{rate:.0}"),
            format!("{:.2}×", rate / base),
            format!("{:.2}", report.mean_batch),
            format!("{:.2}", report.latency.p50_us / 1e3),
            format!("{:.2}", report.latency.p95_us / 1e3),
            format!("{:.2}", report.latency.p99_us / 1e3),
        ]);
        series.push((max_batch, rate, report));
    }
    t.print();
    t.write_csv(&format!("bench_out/serve_throughput_{name}.csv")).ok();
    series
}

/// Overload QoS: one worker, a best-effort flood (every other client
/// with a tight deadline), an interactive trickle. Returns whether the
/// acceptance criteria held.
fn overload_qos() -> bool {
    let cfg = parse_net(CONV).expect("net parses");
    let engine = ServeEngine::start(
        &cfg,
        ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait_us: 2_000,
            queue_cap: 256,
            adaptive_wait: true,
            ..Default::default()
        },
    )
    .expect("engine starts");
    let len = engine.sample_len();

    const BE_CLIENTS: usize = 8;
    const BE_PER_CLIENT: usize = 300;
    const IA_CLIENTS: usize = 2;
    const IA_PER_CLIENT: usize = 60;

    std::thread::scope(|scope| {
        for c in 0..BE_CLIENTS {
            let handle = engine.handle();
            scope.spawn(move || {
                let mut rng = Pcg64::new(0xbe + c as u64);
                let mut sample = vec![0f32; len];
                rng.fill_uniform(&mut sample, -1.0, 1.0);
                // Even clients carry a deadline far tighter than the
                // backlog's queueing delay — their requests expire in
                // the queue and must be shed; odd clients ride the
                // backlog out and define the best-effort latency tail.
                let opts = if c % 2 == 0 {
                    InferOptions::best_effort().with_deadline_us(1_500)
                } else {
                    InferOptions::best_effort()
                };
                let mut pending = Vec::new();
                for _ in 0..BE_PER_CLIENT {
                    match handle.try_infer_with(&sample, opts) {
                        Ok(p) => pending.push(p),
                        Err(SubmitError::QueueFull) => {} // shed at the door
                        Err(_) => break,
                    }
                }
                for p in pending {
                    let _ = p.wait_outcome();
                }
            });
        }
        for c in 0..IA_CLIENTS {
            let handle = engine.handle();
            scope.spawn(move || {
                let mut rng = Pcg64::new(0x1a + c as u64);
                let mut sample = vec![0f32; len];
                rng.fill_uniform(&mut sample, -1.0, 1.0);
                for _ in 0..IA_PER_CLIENT {
                    let _ = handle.infer(&sample); // blocking, interactive lane
                }
            });
        }
    });
    let report = engine.shutdown();

    let ia = *report.lane(Lane::Interactive);
    let be = *report.lane(Lane::BestEffort);
    let mut t = Table::new(
        "Overload QoS: convserve, 1 worker, best-effort flood vs interactive trickle",
        &["lane", "completed", "p50 ms", "p99 ms", "max ms"],
    );
    for (name, lane) in [("interactive", &ia), ("best-effort", &be)] {
        t.row(&[
            name.to_string(),
            lane.completed.to_string(),
            format!("{:.2}", lane.latency.p50_us / 1e3),
            format!("{:.2}", lane.latency.p99_us / 1e3),
            format!("{:.2}", lane.latency.max_us / 1e3),
        ]);
    }
    t.print();
    println!(
        "sheds: {} expired (deadline) + {} rejected (backpressure); {} batches, mean batch {:.2}",
        report.expired, report.rejected, report.batches, report.mean_batch
    );

    let shed_ok = report.expired > 0;
    let prio_ok =
        ia.completed > 0 && be.completed > 0 && ia.latency.p99_us < be.latency.p99_us;
    println!(
        "acceptance: sheds before forward pass {} (expired {}), interactive p99 < best-effort p99 {} ({:.2} ms vs {:.2} ms)",
        if shed_ok { "PASS" } else { "FAIL" },
        report.expired,
        if prio_ok { "PASS" } else { "FAIL" },
        ia.latency.p99_us / 1e3,
        be.latency.p99_us / 1e3
    );
    let allocs_ok = report.worker_steady_allocs.iter().all(|&a| a == 0);
    if !allocs_ok {
        println!(
            "  REGRESSION: overload worker steady-state allocs {:?} (expected all 0)",
            report.worker_steady_allocs
        );
    }
    shed_ok && prio_ok && allocs_ok
}

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    let mut all_zero_allocs = true;
    for (name, cfg) in [("tinyserve", TINY), ("convserve", CONV)] {
        let series = sweep(name, cfg);
        let base = series[0].1;
        let (best_b, best_rate) = series
            .iter()
            .map(|&(b, r, _)| (b, r))
            .fold((1, 0.0), |acc, x| if x.1 > acc.1 { x } else { acc });
        println!(
            "{name}: best micro-batched throughput {best_rate:.0} req/s at max_batch={best_b} — {:.2}× batch-1 ({base:.0} req/s) at the same {WORKERS} workers (acceptance: ≥3×)",
            best_rate / base
        );
        for (b, _, report) in &series {
            if report.worker_steady_allocs.iter().any(|&a| a != 0) {
                all_zero_allocs = false;
                println!(
                    "  REGRESSION: max_batch={b} worker steady-state allocs {:?} (expected all 0)",
                    report.worker_steady_allocs
                );
            }
        }
    }
    println!(
        "steady-state serve-loop tensor allocations: {}",
        if all_zero_allocs { "0 across every config (plan-once holds)" } else { "NONZERO — see above" }
    );
    println!();
    let qos_ok = overload_qos();
    println!(
        "overload QoS acceptance: {}",
        if qos_ok { "PASS (sheds before FLOPs, interactive p99 bounded)" } else { "FAIL — see above" }
    );
}
