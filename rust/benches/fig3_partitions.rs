//! E-fig3: Fig 3 — the impact of batch partitioning on end-to-end
//! CaffeNet execution (256 images/iteration, c4.4xlarge, 16 threads).
//!
//! Two components:
//! * **model** — end-to-end conv-stack time vs partition count on the
//!   c4.8xlarge device model: "None" is the Caffe strategy (per-image
//!   lowering); p = 1..16 partitions of 256 images with 16/p GEMM
//!   threads each, partitions in parallel (the paper's setup).
//! * **measured** — real partitioned execution of a conv2-scale layer
//!   on this machine (1 core: wall times show overhead structure, not
//!   scaling; EXPERIMENTS.md discusses). Partition workers run through
//!   the buffer-writing `conv_type1_into` entry point: each worker
//!   lowers straight out of the shared input slice and writes its
//!   disjoint output slice — no staging copies, no allocator
//!   contention between workers.
//!
//! Run: `cargo bench --bench fig3_partitions`

use cct::bench_util::{fmt_secs, Table};
use cct::coordinator::{conv_partitioned, BatchStrategy};
use cct::device::profiles;
use cct::lowering::{ConvShape, CostModel, LoweringType};
use cct::net::presets;
use cct::rng::Pcg64;
use cct::tensor::Tensor;

/// Simulated conv-stack time for `p` partitions of 256 on a 16-core
/// machine: partitions run concurrently on 16/p cores each, so the
/// makespan is one partition's time with threads=16/p.
fn model_time(p: usize, per_image: bool) -> f64 {
    let dev = profiles::c4_8xlarge();
    let mut total = 0.0;
    for (_, n, k, d, o) in presets::fig7_conv_geometry() {
        let cols = (k * k * d) as u64;
        if per_image {
            // Caffe: 256 sequential b=1 lowerings, GEMM on all 16 threads.
            let shape = ConvShape { n, k, d, o, b: 1, pad: 0, stride: 1 };
            let c = CostModel::new(shape).cost(LoweringType::Type1);
            let rows = (c.lowered_data_elems / cols) as usize;
            let lower = (c.lower_writes * 4) as f64 / (dev.mem_gbps * 1e9);
            total += 256.0 * (lower + dev.gemm_seconds(c.gemm_flops, rows, 16));
        } else {
            let bp = 256 / p;
            let shape = ConvShape { n, k, d, o, b: bp, pad: 0, stride: 1 };
            let c = CostModel::new(shape).cost(LoweringType::Type1);
            let rows = (c.lowered_data_elems / cols) as usize;
            // p partitions in parallel; each sees 16/p threads and its
            // own lowering (lowering parallelizes with partitions —
            // the paper's point about coarse-grained parallel lowering).
            let threads = (16 / p).max(1);
            // all p partitions lower concurrently, sharing bandwidth
            let lower = (c.lower_writes * 4) as f64 / (dev.mem_gbps * 1e9 / p as f64);
            // makespan = one partition's GEMM on its 16/p cores
            // (gemm_seconds charges the cores/useful factor internally)
            total += lower + dev.gemm_seconds(c.gemm_flops, rows, threads);
        }
    }
    total
}

fn main() {
    std::fs::create_dir_all("bench_out").ok();

    // ---- model sweep -----------------------------------------------
    // Non-conv time (fc/lrn/pool/relu/data) is strategy-independent in
    // both systems (Caffe already batches those layers). The paper pins
    // conv at 70–90% of Caffe's execution; we take the midpoint (80%)
    // to size the non-conv remainder and also report the bracket.
    let caffe_conv = model_time(1, true);
    let rest = caffe_conv * (1.0 / 0.8 - 1.0);
    let mut t = Table::new(
        "Fig 3 model: CaffeNet e2e, 256 images, 16 threads (c4.8xlarge model; conv = 80% of Caffe)",
        &["partitions", "conv/iter", "e2e/iter", "e2e speedup vs Caffe(None)"],
    );
    t.row(&[
        "None (Caffe)".into(),
        fmt_secs(caffe_conv),
        fmt_secs(caffe_conv + rest),
        "1.00×".into(),
    ]);
    for p in [1usize, 2, 4, 8, 16] {
        let conv = model_time(p, false);
        t.row(&[
            p.to_string(),
            fmt_secs(conv),
            fmt_secs(conv + rest),
            format!("{:.2}×", (caffe_conv + rest) / (conv + rest)),
        ]);
    }
    t.print();
    t.write_csv("bench_out/fig3_model.csv").ok();
    let e2e = |conv_frac: f64| {
        let r = caffe_conv * (1.0 / conv_frac - 1.0);
        (caffe_conv + r) / (model_time(1, false) + r)
    };
    println!(
        "e2e speedup bracket over the paper's 70–90% conv share: {:.1}×–{:.1}× (paper: 4.5×)",
        e2e(0.7),
        e2e(0.9)
    );
    println!("paper Fig 3: all partitionings beat 'None' by ~4.5×; flat across p (GEMM-equivalent).");

    // ---- measured partition strategies on this machine -------------
    let shape = ConvShape { n: 27, k: 5, d: 96, o: 128, b: 16, pad: 2, stride: 1 };
    let mut rng = Pcg64::new(5);
    let data = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
    let w = Tensor::randn(shape.weight_shape(), 0.0, 0.05, &mut rng);
    let mut tm = Table::new(
        "Fig 3 measured (this machine, 1 core): conv2-scale layer, b=16",
        &["strategy", "wall", "GFLOP/s"],
    );
    let flops = CostModel::new(shape).cost(LoweringType::Type1).gemm_flops;
    for strategy in [
        BatchStrategy::CaffeStyle,
        BatchStrategy::FullBatch,
        BatchStrategy::Partitions(2),
        BatchStrategy::Partitions(4),
        BatchStrategy::Partitions(8),
    ] {
        // best of 3
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let (_, stats) = conv_partitioned(&shape, &data, &w, strategy, 1);
            best = best.min(stats.wall_s);
        }
        tm.row(&[
            strategy.to_string(),
            fmt_secs(best),
            format!("{:.2}", flops as f64 / best / 1e9),
        ]);
    }
    tm.print();
    tm.write_csv("bench_out/fig3_measured.csv").ok();
    println!("(1 core ⇒ partitions can't speed up; the batched-vs-per-image gap is the signal.)");
}
