//! E-fig10: synchronous merge vs Hogwild-style bounded-staleness
//! training (DimmWitted-lineage, paper §2.2's data parallelism taken
//! async). For each (workers, staleness) point the bench reports
//!
//! * wall-clock and images/s over a fixed round budget,
//! * rounds-to-target-loss and (proportional) wall-clock-to-target,
//!   where the target is a fixed fraction of the starting loss — the
//!   "statistical efficiency vs hardware efficiency" trade the async
//!   literature actually argues about,
//! * the steady-state allocation counters (must be zero — the async
//!   round loop shares the planned-workspace guarantee).
//!
//! `S = 0` is the synchronous merge run through the async machinery
//! (bit-identical math, different thread lifetimes), so the sync-vs-S=0
//! delta isolates pure scheduling overhead.
//!
//! Run: `cargo bench --bench fig10_async_solver`
//! (set `CCT_BENCH_QUICK=1` for the CI-sized quick mode)
//! Writes `bench_out/BENCH_async_solver.json` for the CI perf-smoke gate.

use cct::bench_util::Table;
use cct::coordinator::{partitioner, AsyncConfig, AsyncCoordinator, CnnCoordinator};
use cct::data::BlobCorpus;
use cct::net::config::{parse_net, NetConfig};
use cct::net::presets;
use cct::solver::SolverConfig;
use cct::tensor::Tensor;

/// Quick-mode model: small enough that the 6-config sweep fits the CI
/// perf-smoke budget on one core, conv-fronted so the GEMM pool is
/// actually exercised.
const SMALL: &str = r#"
name: small
input: 3 16 16
conv { name: c1 out: 8 kernel: 3 pad: 1 std: 0.05 }
relu { name: r1 }
fc   { name: f1 out: 10 std: 0.1 }
"#;

/// Loss target as a fraction of the first round's loss.
const TARGET_FRAC: f64 = 0.8;

fn quick_mode() -> bool {
    std::env::var("CCT_BENCH_QUICK").is_ok()
}

struct Case {
    label: String,
    mode: &'static str,
    workers: usize,
    staleness: usize,
    rounds: usize,
    batch: usize,
    wall_s: f64,
    first_loss: f64,
    final_loss: f64,
    /// 1-based round count to reach `TARGET_FRAC * first_loss`; 0 if
    /// the target was not reached inside the round budget.
    rounds_to_target: usize,
    /// Wall-clock to target, prorated over the measured run (exact for
    /// sync, proportional for async where rounds overlap in time).
    wall_to_target_s: f64,
    steady_tensor_allocs: u64,
    steady_arena_growth: u64,
}

impl Case {
    fn imgs_per_s(&self) -> f64 {
        (self.rounds * self.batch) as f64 / self.wall_s.max(1e-12)
    }
}

fn target_stats(losses: &[f64], wall_s: f64) -> (usize, f64) {
    let target = losses[0] * TARGET_FRAC;
    match losses.iter().position(|&l| l <= target) {
        Some(idx) => (idx + 1, wall_s * (idx + 1) as f64 / losses.len() as f64),
        None => (0, 0.0),
    }
}

fn solver_cfg() -> SolverConfig {
    SolverConfig { base_lr: 0.05, momentum: 0.9, weight_decay: 0.0, ..Default::default() }
}

fn run_sync(cfg: &NetConfig, workers: usize, x: &Tensor, labels: &[usize], batch: usize, rounds: usize) -> Case {
    let mut coord = CnnCoordinator::new(cfg, workers, workers, solver_cfg(), 7).unwrap();
    let n = labels.len();
    let mut losses = Vec::with_capacity(rounds);
    let t0 = std::time::Instant::now();
    for r in 0..rounds {
        let s = partitioner::round_start(n, batch, r);
        losses.push(coord.step(&x.slice_samples(s, s + batch), &labels[s..s + batch]));
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let (rtt, wtt) = target_stats(&losses, wall_s);
    Case {
        label: format!("sync p={workers}"),
        mode: "sync",
        workers,
        staleness: 0,
        rounds,
        batch,
        wall_s,
        first_loss: losses[0],
        final_loss: *losses.last().unwrap(),
        rounds_to_target: rtt,
        wall_to_target_s: wtt,
        steady_tensor_allocs: 0,
        steady_arena_growth: 0,
    }
}

fn run_async(
    cfg: &NetConfig,
    workers: usize,
    staleness: usize,
    x: &Tensor,
    labels: &[usize],
    batch: usize,
    rounds: usize,
) -> Case {
    let acfg = AsyncConfig { workers, total_threads: workers, staleness, seed: 7 };
    let mut coord = AsyncCoordinator::new(cfg, acfg, solver_cfg()).unwrap();
    let rep = coord.run(x, labels, batch, rounds);
    let (rtt, wtt) = target_stats(&rep.round_loss, rep.wall_s);
    Case {
        label: format!("async p={workers} S={staleness}"),
        mode: "async",
        workers,
        staleness,
        rounds,
        batch,
        wall_s: rep.wall_s,
        first_loss: rep.round_loss[0],
        final_loss: rep.final_loss,
        rounds_to_target: rtt,
        wall_to_target_s: wtt,
        steady_tensor_allocs: rep.steady_tensor_allocs,
        steady_arena_growth: rep.steady_arena_growth,
    }
}

/// Hand-rolled JSON for the CI artifact (no serde in-tree).
fn write_bench_json(path: &str, mode: &str, cases: &[Case]) -> std::io::Result<()> {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"fig10_async_solver\",\n");
    out.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    out.push_str(&format!("  \"target_frac\": {TARGET_FRAC},\n"));
    out.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"label\": \"{}\", \"mode\": \"{}\", \"workers\": {}, \"staleness\": {}, \
             \"rounds\": {}, \"batch\": {}, \"wall_s\": {:.6}, \"imgs_per_s\": {:.2}, \
             \"first_loss\": {:.6}, \"final_loss\": {:.6}, \"rounds_to_target\": {}, \
             \"wall_to_target_s\": {:.6}, \"steady_tensor_allocs\": {}, \"steady_arena_growth\": {}}}{}\n",
            c.label,
            c.mode,
            c.workers,
            c.staleness,
            c.rounds,
            c.batch,
            c.wall_s,
            c.imgs_per_s(),
            c.first_loss,
            c.final_loss,
            c.rounds_to_target,
            c.wall_to_target_s,
            c.steady_tensor_allocs,
            c.steady_arena_growth,
            if i + 1 == cases.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    let quick = quick_mode();

    let (cfg, channels, side, classes, batch, rounds) = if quick {
        (parse_net(SMALL).unwrap(), 3, 16, 10, 16, 24)
    } else {
        (parse_net(presets::CIFAR10_QUICK).unwrap(), 3, 32, 10, 32, 40)
    };
    let corpus = BlobCorpus::generate(channels, side, classes, (batch * 4).max(64), 0.2, 7);
    let x = corpus.samples();
    let labels = corpus.labels();

    let workers_sweep: &[usize] = &[1, 8];
    let staleness_sweep: &[usize] = &[0, 1, 4];

    let mut cases = Vec::new();
    for &p in workers_sweep {
        cases.push(run_sync(&cfg, p, x, labels, batch, rounds));
        for &s in staleness_sweep {
            cases.push(run_async(&cfg, p, s, x, labels, batch, rounds));
        }
    }

    let mut t = Table::new(
        &format!("Fig 10: sync vs bounded-staleness async ({}, batch {batch}, {rounds} rounds)", cfg.name),
        &["config", "wall (s)", "img/s", "loss first→final", "rounds→target", "wall→target (s)", "steady allocs"],
    );
    for c in &cases {
        t.row(&[
            c.label.clone(),
            format!("{:.3}", c.wall_s),
            format!("{:.1}", c.imgs_per_s()),
            format!("{:.4}→{:.4}", c.first_loss, c.final_loss),
            if c.rounds_to_target > 0 { c.rounds_to_target.to_string() } else { "-".into() },
            if c.rounds_to_target > 0 { format!("{:.3}", c.wall_to_target_s) } else { "-".into() },
            format!("{}t/{}a", c.steady_tensor_allocs, c.steady_arena_growth),
        ]);
    }
    t.print();
    t.write_csv("bench_out/fig10_async_solver.csv").ok();

    // Headline claims, mirroring the CI gate (generous noise floors —
    // the gate enforces "not slower within noise", the 1.0× target is
    // reported).
    let sync8 = cases.iter().find(|c| c.mode == "sync" && c.workers == 8).unwrap();
    let async8 = cases
        .iter()
        .filter(|c| c.mode == "async" && c.workers == 8)
        .max_by(|a, b| a.imgs_per_s().total_cmp(&b.imgs_per_s()))
        .unwrap();
    println!(
        "\nCLAIM async throughput ≥ sync at p=8 (best staleness, ±10% noise): {} ({} {:.1} img/s vs sync {:.1} img/s)",
        if async8.imgs_per_s() >= sync8.imgs_per_s() * 0.9 { "PASS" } else { "FAIL" },
        async8.label,
        async8.imgs_per_s(),
        sync8.imgs_per_s()
    );
    let allocs_ok = cases.iter().all(|c| c.steady_tensor_allocs == 0 && c.steady_arena_growth == 0);
    println!(
        "CLAIM zero steady-state allocations in every async round loop: {}",
        if allocs_ok { "PASS" } else { "FAIL" }
    );

    write_bench_json("bench_out/BENCH_async_solver.json", if quick { "quick" } else { "full" }, &cases)
        .expect("writing BENCH_async_solver.json");
    println!("wrote bench_out/BENCH_async_solver.json");
}
