//! E-fig5: Fig 5 — hybrid CPU/GPU scheduling, simulated *and* executed.
//!
//! Part 1 (the original table): multi-GPU end-to-end AlexNet on the
//! g2.8xlarge model (4× GRID K520 + host CPU): 1 GPU, 1 GPU + CPU,
//! 4 GPU. FLOPS-proportional data parallelism per layer (the paper's
//! scheme; no model parallelism for FC — the paper notes that
//! limitation too). Pure cost-model simulation.
//!
//! Part 2 (the executed check): the same FLOPS-proportional scheduler
//! drives [`conv_hybrid`] end to end over asymmetric [`SimBackend`]
//! fleets — real partition workers, real lowering/GEMM/lift on every
//! device handle, profile-derived latency injection. The measured
//! per-device makespans are compared against the cost model's
//! predictions:
//!
//! * **2-device gated case** (c4.4xlarge + g2 host CPU, both
//!   host-resident so executed charges and the model agree op for op):
//!   CI fails if the measured device-time *ratio* deviates from the
//!   predicted ratio by more than 10%, or if the hybrid output is not
//!   numerically identical to the single-device reference.
//! * **3-device reported case** (adds a GRID K520): exercises PCIe
//!   transfer charges too. Reported, not gated — the executed path
//!   charges transfer + compute additively while the model overlaps
//!   them (`max`), so a small systematic gap is expected.
//!
//! Machine-readable output: `bench_out/BENCH_hybrid.json`.
//!
//! Run: `cargo bench --bench fig5_multigpu`
//! (set `CCT_BENCH_QUICK=1` for the CI-sized quick mode)

use cct::bench_util::{fmt_secs, Table};
use cct::coordinator::{conv_hybrid, scheduler};
use cct::device::{profiles, DeviceSpec};
use cct::exec::{Backend, SimBackend};
use cct::lowering::{type1, ConvShape, LoweringType};
use cct::net::presets;
use cct::rng::Pcg64;
use cct::tensor::Tensor;
use std::time::Instant;

fn quick() -> bool {
    std::env::var("CCT_BENCH_QUICK").is_ok()
}

fn e2e(devices: &[DeviceSpec]) -> f64 {
    presets::fig7_conv_geometry()
        .into_iter()
        .map(|(_, n, k, d, o)| {
            let shape = ConvShape { n, k, d, o, b: 256, pad: 0, stride: 1 };
            scheduler::schedule_and_simulate(&shape, devices, LoweringType::Type1).makespan_s
        })
        .sum()
}

/// One executed hybrid scenario next to its cost-model prediction.
struct Executed {
    names: Vec<String>,
    assignment: Vec<usize>,
    /// Model seconds per device (unscaled).
    predicted_s: Vec<f64>,
    /// Wall seconds each partition worker measured (scaled by
    /// `time_scale`).
    measured_s: Vec<f64>,
    predicted_makespan_s: f64,
    measured_makespan_s: f64,
    time_scale: f64,
    /// Largest |hybrid − reference| output element.
    max_abs_diff: f32,
}

impl Executed {
    /// device-0 : device-1 time ratio as the model predicts it.
    fn predicted_ratio(&self) -> f64 {
        self.predicted_s[0] / self.predicted_s[1].max(1e-300)
    }

    /// The same ratio as actually measured (time_scale cancels).
    fn measured_ratio(&self) -> f64 {
        self.measured_s[0] / self.measured_s[1].max(1e-300)
    }

    /// Relative error of the measured ratio vs the predicted one.
    fn ratio_rel_err(&self) -> f64 {
        (self.measured_ratio() / self.predicted_ratio() - 1.0).abs()
    }
}

/// Run the FLOPS-proportional scheduler end to end over `specs` as
/// latency-injecting [`SimBackend`]s, one single-threaded partition
/// worker per device.
fn run_executed(
    shape: &ConvShape,
    specs: &[DeviceSpec],
    time_scale: f64,
    data: &Tensor,
    weights: &Tensor,
    reference: &Tensor,
) -> Executed {
    let sims: Vec<SimBackend> =
        specs.iter().map(|s| SimBackend::new(s.clone(), time_scale, 1)).collect();
    let fleet: Vec<&dyn Backend> = sims.iter().map(|s| s as &dyn Backend).collect();
    let (out, stats) = conv_hybrid(shape, data, weights, &fleet, fleet.len());
    let plan = scheduler::simulate_hybrid_conv(shape, specs, &stats.assignment, LoweringType::Type1);
    let max_abs_diff = out
        .as_slice()
        .iter()
        .zip(reference.as_slice())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    Executed {
        names: specs.iter().map(|s| s.name.clone()).collect(),
        assignment: stats.assignment,
        predicted_s: plan.per_device_s,
        measured_s: stats.per_device_s,
        predicted_makespan_s: plan.makespan_s,
        measured_makespan_s: stats.makespan_s,
        time_scale,
        max_abs_diff,
    }
}

/// Pick `time_scale` so the *smallest active* partition's injected
/// latency is still `slowdown ×` the real full-batch conv time — the
/// sleeps then dominate the underlying CPU compute on every device and
/// the measured asymmetry is the modeled asymmetry.
fn calibrate(shape: &ConvShape, specs: &[DeviceSpec], t_real: f64, slowdown: f64) -> f64 {
    let assignment = scheduler::flops_proportional_split(shape.b, specs);
    let plan = scheduler::simulate_hybrid_conv(shape, specs, &assignment, LoweringType::Type1);
    let min_active =
        plan.per_device_s.iter().copied().filter(|&s| s > 0.0).fold(f64::INFINITY, f64::min);
    assert!(min_active.is_finite(), "no active device in the plan");
    slowdown * t_real / min_active
}

fn executed_table(title: &str, ex: &Executed) -> Table {
    let mut t = Table::new(
        title,
        &["device", "samples", "predicted (model)", "measured (wall)", "meas/pred·scale"],
    );
    for i in 0..ex.names.len() {
        let scaled_pred = ex.predicted_s[i] * ex.time_scale;
        t.row(&[
            ex.names[i].clone(),
            ex.assignment[i].to_string(),
            fmt_secs(ex.predicted_s[i]),
            fmt_secs(ex.measured_s[i]),
            if scaled_pred > 0.0 {
                format!("{:.3}", ex.measured_s[i] / scaled_pred)
            } else {
                "-".into()
            },
        ]);
    }
    t
}

fn write_bench_json(
    path: &str,
    mode: &str,
    shape: &ConvShape,
    t_real: f64,
    two: &Executed,
    three: &Executed,
) -> std::io::Result<()> {
    fn scenario(out: &mut String, key: &str, ex: &Executed, last: bool) {
        let names: Vec<String> = ex.names.iter().map(|n| format!("\"{n}\"")).collect();
        let pred: Vec<String> = ex.predicted_s.iter().map(|s| format!("{s:.9}")).collect();
        let meas: Vec<String> = ex.measured_s.iter().map(|s| format!("{s:.9}")).collect();
        out.push_str(&format!("  \"{key}\": {{\n"));
        out.push_str(&format!("    \"devices\": [{}],\n", names.join(", ")));
        out.push_str(&format!("    \"assignment\": {:?},\n", ex.assignment));
        out.push_str(&format!("    \"predicted_s\": [{}],\n", pred.join(", ")));
        out.push_str(&format!("    \"measured_s\": [{}],\n", meas.join(", ")));
        out.push_str(&format!("    \"predicted_ratio\": {:.6},\n", ex.predicted_ratio()));
        out.push_str(&format!("    \"measured_ratio\": {:.6},\n", ex.measured_ratio()));
        out.push_str(&format!("    \"ratio_rel_err\": {:.6},\n", ex.ratio_rel_err()));
        out.push_str(&format!("    \"predicted_makespan_s\": {:.9},\n", ex.predicted_makespan_s));
        out.push_str(&format!("    \"measured_makespan_s\": {:.9},\n", ex.measured_makespan_s));
        out.push_str(&format!("    \"time_scale\": {:.3},\n", ex.time_scale));
        out.push_str(&format!("    \"max_abs_diff\": {:e}\n", ex.max_abs_diff));
        out.push_str(&format!("  }}{}\n", if last { "" } else { "," }));
    }
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"fig5_hybrid\",\n");
    out.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    out.push_str(&format!(
        "  \"shape\": {{\"n\": {}, \"k\": {}, \"d\": {}, \"o\": {}, \"b\": {}, \"pad\": {}, \"stride\": {}}},\n",
        shape.n, shape.k, shape.d, shape.o, shape.b, shape.pad, shape.stride
    ));
    out.push_str(&format!("  \"calibration_conv_s\": {t_real:.9},\n"));
    scenario(&mut out, "two_device", two, false);
    scenario(&mut out, "three_device", three, true);
    out.push_str("}\n");
    std::fs::write(path, out)
}

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    let q = quick();

    // ---- Part 1: analytic simulation (the original Fig 5 table) ----
    let gpu = profiles::grid_k520();
    let cpu = profiles::g2_8xlarge_cpu();

    let one = e2e(std::slice::from_ref(&gpu));
    let one_cpu = e2e(&[gpu.clone(), cpu.clone()]);
    let four = e2e(&[gpu.clone(), gpu.clone(), gpu.clone(), gpu.clone()]);

    let mut t = Table::new(
        "Fig 5: e2e AlexNet conv stack on g2.8xlarge model (256 images/iter)",
        &["config", "time", "speedup", "paper time (s)", "paper speedup"],
    );
    t.row(&["1 GPU".into(), fmt_secs(one), "1.00×".into(), "2.75".into(), "1.00×".into()]);
    t.row(&[
        "1 GPU + CPU".into(),
        fmt_secs(one_cpu),
        format!("{:.2}×", one / one_cpu),
        "2.35".into(),
        "1.17×".into(),
    ]);
    t.row(&[
        "4 GPU".into(),
        fmt_secs(four),
        format!("{:.2}×", one / four),
        "0.88".into(),
        "3.12×".into(),
    ]);
    t.print();
    t.write_csv("bench_out/fig5.csv").ok();
    println!("\npaper: adding the host CPU gives >15%; 4 GPUs give >3× (4× blocked on FC model parallelism).");

    // ---- Part 2: executed hybrid over SimBackends ----
    let b = if q { 48 } else { 96 };
    let shape = ConvShape { n: 16, k: 3, d: 8, o: 16, b, pad: 1, stride: 1 };
    let mut rng = Pcg64::new(42);
    let data = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
    let weights = Tensor::randn(shape.weight_shape(), 0.0, 0.1, &mut rng);

    // Single-device reference output doubles as the latency
    // calibration: how long one real full-batch conv takes here.
    let mut reference = Tensor::zeros(shape.output_shape());
    let mut ws = type1::Workspace::new(&shape);
    let t0 = Instant::now();
    type1::conv_type1_into(
        &shape,
        data.as_slice(),
        weights.as_slice(),
        1,
        &mut ws,
        reference.as_mut_slice(),
    );
    let t_real = t0.elapsed().as_secs_f64().max(1e-6);

    let slowdown = if q { 25.0 } else { 40.0 };

    // Gated pair: both host-resident, so the executed charges and the
    // scheduler's conv_seconds agree term for term and the only error
    // left is real compute bleeding past the injected sleeps.
    let pair = [profiles::c4_4xlarge(), profiles::g2_host_cpu()];
    let two = run_executed(
        &shape,
        &pair,
        calibrate(&shape, &pair, t_real, slowdown),
        &data,
        &weights,
        &reference,
    );
    executed_table(
        &format!("Executed hybrid conv (b={b}) on 2 simulated asymmetric devices"),
        &two,
    )
    .print();

    // Reported trio: adds a PCIe-attached GPU profile. The executed
    // path charges transfers additively while the model overlaps them,
    // so this one is informative, not gated.
    let trio = [profiles::grid_k520(), profiles::c4_4xlarge(), profiles::g2_host_cpu()];
    let three = run_executed(
        &shape,
        &trio,
        calibrate(&shape, &trio, t_real, slowdown),
        &data,
        &weights,
        &reference,
    );
    executed_table(
        &format!("Executed hybrid conv (b={b}) on 3 simulated devices (GPU pays PCIe; reported)"),
        &three,
    )
    .print();

    let ratio_ok = two.ratio_rel_err() <= 0.10;
    let bits_ok = two.max_abs_diff == 0.0;
    println!(
        "\nCLAIM measured device-time ratio tracks the cost model within 10% (2-device): {} \
         (predicted {:.3}, measured {:.3}, rel err {:.1}%)",
        if ratio_ok { "PASS" } else { "FAIL" },
        two.predicted_ratio(),
        two.measured_ratio(),
        two.ratio_rel_err() * 100.0
    );
    println!(
        "CLAIM hybrid output identical to single-device reference: {} (max |Δ| = {:e})",
        if bits_ok { "PASS" } else { "FAIL" },
        two.max_abs_diff
    );
    println!(
        "3-device (reported): predicted ratio d0/d1 {:.3}, measured {:.3}, rel err {:.1}%, max |Δ| = {:e}",
        three.predicted_ratio(),
        three.measured_ratio(),
        three.ratio_rel_err() * 100.0,
        three.max_abs_diff
    );

    write_bench_json(
        "bench_out/BENCH_hybrid.json",
        if q { "quick" } else { "full" },
        &shape,
        t_real,
        &two,
        &three,
    )
    .expect("writing BENCH_hybrid.json");
    println!("wrote bench_out/BENCH_hybrid.json");
}
