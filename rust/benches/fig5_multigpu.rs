//! E-fig5: Fig 5 — multi-GPU end-to-end AlexNet on the g2.8xlarge
//! model (4× GRID K520 + host CPU): 1 GPU, 1 GPU + CPU, 4 GPU.
//! FLOPS-proportional data parallelism per layer (the paper's scheme;
//! no model parallelism for FC — the paper notes that limitation too).
//!
//! Run: `cargo bench --bench fig5_multigpu`

use cct::bench_util::{fmt_secs, Table};
use cct::coordinator::scheduler;
use cct::device::{profiles, DeviceSpec};
use cct::lowering::{ConvShape, LoweringType};
use cct::net::presets;

fn e2e(devices: &[DeviceSpec]) -> f64 {
    presets::fig7_conv_geometry()
        .into_iter()
        .map(|(_, n, k, d, o)| {
            let shape = ConvShape { n, k, d, o, b: 256, pad: 0, stride: 1 };
            scheduler::schedule_and_simulate(&shape, devices, LoweringType::Type1).makespan_s
        })
        .sum()
}

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    let gpu = profiles::grid_k520();
    let cpu = profiles::g2_8xlarge_cpu();

    let one = e2e(std::slice::from_ref(&gpu));
    let one_cpu = e2e(&[gpu.clone(), cpu.clone()]);
    let four = e2e(&[gpu.clone(), gpu.clone(), gpu.clone(), gpu.clone()]);

    let mut t = Table::new(
        "Fig 5: e2e AlexNet conv stack on g2.8xlarge model (256 images/iter)",
        &["config", "time", "speedup", "paper time (s)", "paper speedup"],
    );
    t.row(&["1 GPU".into(), fmt_secs(one), "1.00×".into(), "2.75".into(), "1.00×".into()]);
    t.row(&[
        "1 GPU + CPU".into(),
        fmt_secs(one_cpu),
        format!("{:.2}×", one / one_cpu),
        "2.35".into(),
        "1.17×".into(),
    ]);
    t.row(&[
        "4 GPU".into(),
        fmt_secs(four),
        format!("{:.2}×", one / four),
        "0.88".into(),
        "3.12×".into(),
    ]);
    t.print();
    t.write_csv("bench_out/fig5.csv").ok();
    println!("\npaper: adding the host CPU gives >15%; 4 GPUs give >3× (4× blocked on FC model parallelism).");
}
