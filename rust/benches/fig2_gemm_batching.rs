//! E-fig2: Fig 2(a,b,c) — the impact of batch size and thread count on
//! the GEMM kernel.
//!
//! * (a) speedup vs #threads at several batch sizes — device model
//!   (this testbed has 1 core; the model's efficiency curve is
//!   calibrated from the measured single-core numbers below).
//! * (b) speedup vs batch size at 8 threads — model, plus the
//!   *measured* single-core GFLOP/s of thin-vs-fat lowered matrices
//!   (the mechanism).
//! * (c) memory footprint vs batch size — exact (workspace bytes).
//! * (d) planned-workspace execution — tensor allocations per training
//!   step before vs after the first (planning) step, measured via the
//!   `tensor::alloc_stats` hook: the hot loop is allocation-free.
//! * (e) **pool vs spawn-per-call** (PR 5) — the persistent worker
//!   pool against the old scoped-spawn threaded GEMM on the CaffeNet
//!   conv2 shape across batch sizes, at the paper's t=8 thread
//!   setting. Also asserts the pool's zero-steady-state-allocation
//!   guarantee and writes a machine-readable `BENCH_gemm.json` for the
//!   CI perf-smoke gate.
//!
//! Run: `cargo bench --bench fig2_gemm_batching`
//! (set `CCT_BENCH_QUICK=1` for the CI-sized quick mode)

use cct::bench_util::{bench, gflops, Table};
use cct::device::profiles;
use cct::gemm::{gemm_flops, gemm_spawn, pool, sgemm, GemmDims, Trans};
use cct::layers::ExecCtx;
use cct::lowering::{type1, ConvShape};
use cct::net::{config::build_net, parse_net, presets};
use cct::rng::Pcg64;
use cct::tensor::{alloc_stats, Tensor};

/// conv2's GEMM geometry (Fig 7): k²d = 2400, o = 256, m² = 529/image.
const COLS: usize = 2400;
const OUT: usize = 256;
const ROWS_PER_IMAGE: usize = 529;
/// The paper's Fig 2 thread setting: the budget both contenders in
/// section (e) are asked for (the pool clamps it to the machine; the
/// spawn baseline spawns that many OS threads per call, as it always
/// did).
const BUDGET_THREADS: usize = 8;

fn quick_mode() -> bool {
    std::env::var("CCT_BENCH_QUICK").is_ok()
}

fn measured_gflops(rows: usize, reps: usize) -> f64 {
    let mut rng = Pcg64::new(41);
    let mut a = vec![0f32; rows * COLS];
    let mut b = vec![0f32; COLS * OUT];
    rng.fill_uniform(&mut a, -1.0, 1.0);
    rng.fill_uniform(&mut b, -1.0, 1.0);
    let mut c = vec![0f32; rows * OUT];
    let dims = GemmDims { m: rows, n: OUT, k: COLS };
    let st = bench(1, reps, || {
        sgemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut c, 1);
    });
    gflops(gemm_flops(dims), st.min)
}

/// One section-(e) case: conv2's lowered GEMM at batch `b`, spawn
/// baseline vs pool, same thread budget.
struct PoolCase {
    batch: usize,
    rows: usize,
    spawn_s: f64,
    pool_s: f64,
}

impl PoolCase {
    fn speedup(&self) -> f64 {
        self.spawn_s / self.pool_s.max(1e-12)
    }
}

fn run_pool_case(batch: usize, warmup: usize, iters: usize) -> PoolCase {
    let rows = batch * ROWS_PER_IMAGE;
    let dims = GemmDims { m: rows, n: OUT, k: COLS };
    let mut rng = Pcg64::new(4100 + batch as u64);
    let mut a = vec![0f32; rows * COLS];
    let mut b = vec![0f32; COLS * OUT];
    rng.fill_uniform(&mut a, -1.0, 1.0);
    rng.fill_uniform(&mut b, -1.0, 1.0);
    let mut c = vec![0f32; rows * OUT];
    let spawn = bench(warmup, iters, || {
        gemm_spawn(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut c, BUDGET_THREADS);
    });
    let pooled = bench(warmup, iters, || {
        sgemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut c, BUDGET_THREADS);
    });
    PoolCase { batch, rows, spawn_s: spawn.min, pool_s: pooled.min }
}

/// Hand-rolled JSON for the CI artifact (no serde in-tree).
fn write_bench_json(
    path: &str,
    mode: &str,
    cases: &[PoolCase],
    arena_growth: u64,
    tensor_allocs: u64,
) -> std::io::Result<()> {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"fig2_gemm_batching\",\n");
    out.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    out.push_str(&format!("  \"pool_workers\": {},\n", pool::global_workers()));
    out.push_str(&format!("  \"budget_threads\": {BUDGET_THREADS},\n"));
    out.push_str(&format!(
        "  \"conv2_dims\": {{\"n\": {OUT}, \"k\": {COLS}, \"rows_per_image\": {ROWS_PER_IMAGE}}},\n"
    ));
    out.push_str("  \"cases\": [\n");
    for (i, case) in cases.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"batch\": {}, \"rows\": {}, \"spawn_s\": {:.6}, \"pool_s\": {:.6}, \"speedup\": {:.4}}}{}\n",
            case.batch,
            case.rows,
            case.spawn_s,
            case.pool_s,
            case.speedup(),
            if i + 1 == cases.len() { "" } else { "," }
        ));
    }
    out.push_str("  ],\n");
    let large = cases.last().expect("at least one case");
    out.push_str(&format!(
        "  \"large_batch\": {{\"batch\": {}, \"speedup\": {:.4}}},\n",
        large.batch,
        large.speedup()
    ));
    out.push_str(&format!("  \"steady_arena_growth\": {arena_growth},\n"));
    out.push_str(&format!("  \"steady_tensor_allocs\": {tensor_allocs}\n"));
    out.push_str("}\n");
    std::fs::write(path, out)
}

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    let quick = quick_mode();
    let dev = profiles::c4_4xlarge();
    let flops_per_image = gemm_flops(GemmDims { m: ROWS_PER_IMAGE, n: OUT, k: COLS });

    // ---- (a) speedup vs threads, per batch size (model) ------------
    let mut ta = Table::new(
        "Fig 2(a/b) model: GEMM speedup vs threads (c4.4xlarge model, conv2 GEMM)",
        &["batch", "t=1", "t=2", "t=4", "t=8"],
    );
    for b in [1usize, 4, 16, 64, 256] {
        let rows = b * ROWS_PER_IMAGE;
        let flops = flops_per_image * b as u64;
        let t1 = dev.gemm_seconds(flops, rows, 1);
        let row: Vec<String> = [1usize, 2, 4, 8]
            .iter()
            .map(|&t| format!("{:.2}×", t1 / dev.gemm_seconds(flops, rows, t)))
            .collect();
        ta.row(&[b.to_string(), row[0].clone(), row[1].clone(), row[2].clone(), row[3].clone()]);
    }
    ta.print();
    ta.write_csv("bench_out/fig2a_model.csv").ok();
    println!("paper Fig 2(a): near-linear to 4 cores at b=256; Fig 2(b): smaller b ⇒ lower speedup.");

    // ---- (b) measured single-core: thin vs fat lowered matrices ----
    let mut tb = Table::new(
        "Fig 2(b) measured (this machine, 1 core): GEMM throughput vs lowered batch",
        &["batch (rows)", "GFLOP/s", "vs b=1"],
    );
    let (b_list, reps): (&[usize], usize) =
        if quick { (&[1, 4, 8], 1) } else { (&[1, 2, 4, 8, 16], 2) };
    let base = measured_gflops(ROWS_PER_IMAGE, if quick { 1 } else { 3 });
    for &b in b_list {
        let g = if b == 1 { base } else { measured_gflops(b * ROWS_PER_IMAGE, reps) };
        tb.row(&[
            format!("{b} ({})", b * ROWS_PER_IMAGE),
            format!("{g:.2}"),
            format!("{:.2}×", g / base),
        ]);
    }
    tb.print();
    tb.write_csv("bench_out/fig2b_measured.csv").ok();

    // ---- (c) memory footprint vs batch (exact) ---------------------
    let mut tc = Table::new(
        "Fig 2(c): lowered-matrix memory footprint vs batch (conv2, exact)",
        &["batch", "lowered MiB", "vs b=1"],
    );
    let bytes1 = type1::Workspace::new(&ConvShape { n: 27, k: 5, d: 96, o: 256, b: 1, pad: 2, stride: 1 }).bytes();
    for b in [1usize, 16, 64, 128, 256] {
        let shape = ConvShape { n: 27, k: 5, d: 96, o: 256, b, pad: 2, stride: 1 };
        let bytes = type1::Workspace::new(&shape).bytes();
        tc.row(&[
            b.to_string(),
            format!("{:.1}", bytes as f64 / (1 << 20) as f64),
            format!("{:.0}×", bytes as f64 / bytes1 as f64),
        ]);
    }
    tc.print();
    tc.write_csv("bench_out/fig2c_footprint.csv").ok();
    println!("paper Fig 2(c): footprint directly proportional to b.");

    // ---- (d) plan-once / run-many: tensor allocs per step ----------
    let cfg = parse_net(presets::CIFAR10_QUICK).expect("preset parses");
    let mut rng = Pcg64::new(42);
    let mut net = build_net(&cfg, &mut rng).expect("preset builds");
    let x = Tensor::randn((16, 3, 32, 32), 0.0, 1.0, &mut rng);
    let labels: Vec<usize> = (0..16).map(|i| i % 10).collect();
    let ctx = ExecCtx::default();
    let mut td = Table::new(
        "Plan-once/run-many: tensor allocations per forward_backward (cifar10_quick, b=16)",
        &["step", "tensor allocs"],
    );
    for step in 0..4 {
        let snap = alloc_stats::tensor_allocs();
        let _ = net.forward_backward(&x, &labels, &ctx);
        td.row(&[
            if step == 0 { "1 (plans workspace)".into() } else { format!("{}", step + 1) },
            alloc_stats::allocs_since(snap).to_string(),
        ]);
    }
    td.print();
    println!("steps after the first run entirely inside the planned arena (0 allocs).");

    // ---- (e) pool vs spawn-per-call (PR 5) -------------------------
    let (e_batches, e_warm, e_iters): (&[usize], usize, usize) =
        if quick { (&[1, 4, 16], 1, 3) } else { (&[1, 2, 4, 8, 16], 1, 4) };
    pool::prewarm(); // start the pool + warm this thread's arena up front
    let mut te = Table::new(
        &format!(
            "Fig 2(e): persistent pool vs spawn-per-call GEMM (conv2 shape, thread budget {BUDGET_THREADS}, pool = {} workers + submitter)",
            pool::global_workers()
        ),
        &["batch", "rows", "spawn ms", "pool ms", "pool speedup"],
    );
    let mut cases = Vec::new();
    for &b in e_batches {
        let case = run_pool_case(b, e_warm, e_iters);
        te.row(&[
            case.batch.to_string(),
            case.rows.to_string(),
            format!("{:.2}", case.spawn_s * 1e3),
            format!("{:.2}", case.pool_s * 1e3),
            format!("{:.2}×", case.speedup()),
        ]);
        cases.push(case);
    }
    te.print();
    te.write_csv("bench_out/fig2e_pool_vs_spawn.csv").ok();

    // Steady-state guarantee on the large-batch case: zero tensor
    // allocations and zero packing-arena growth on this (warmed)
    // submitter thread; worker arenas were planned at spawn.
    let large = *e_batches.last().unwrap();
    let rows = large * ROWS_PER_IMAGE;
    let dims = GemmDims { m: rows, n: OUT, k: COLS };
    let mut rng2 = Pcg64::new(77);
    let mut a = vec![0f32; rows * COLS];
    let mut bm = vec![0f32; COLS * OUT];
    rng2.fill_uniform(&mut a, -1.0, 1.0);
    rng2.fill_uniform(&mut bm, -1.0, 1.0);
    let mut c = vec![0f32; rows * OUT];
    sgemm(Trans::N, Trans::N, dims, 1.0, &a, &bm, 0.0, &mut c, BUDGET_THREADS); // warm
    let arena_snap = pool::arena_allocs();
    let tensor_snap = alloc_stats::tensor_allocs();
    for _ in 0..3 {
        sgemm(Trans::N, Trans::N, dims, 1.0, &a, &bm, 0.0, &mut c, BUDGET_THREADS);
    }
    let arena_growth = pool::arena_allocs() - arena_snap;
    let tensor_allocs = alloc_stats::allocs_since(tensor_snap);

    let every_batch_ok = cases.iter().all(|c| c.speedup() >= 0.95);
    let large_speedup = cases.last().unwrap().speedup();
    println!(
        "\nCLAIM pool ≥ spawn-per-call at every batch size (±5% timer noise): {} ({})",
        if every_batch_ok { "PASS" } else { "FAIL" },
        cases.iter().map(|c| format!("b={}: {:.2}×", c.batch, c.speedup())).collect::<Vec<_>>().join(", ")
    );
    println!(
        "TARGET pool ≥ 1.3× spawn on the CaffeNet-shaped large-batch case (b={large}): {} (measured {large_speedup:.2}×; reported, not CI-gated — the gate enforces not-slower within noise)",
        if large_speedup >= 1.3 { "MET" } else { "NOT MET" }
    );
    println!(
        "CLAIM zero steady-state allocations (pool GEMM hot loop): {} (arena growth {arena_growth}, tensor allocs {tensor_allocs})",
        if arena_growth == 0 && tensor_allocs == 0 { "PASS" } else { "FAIL" }
    );

    write_bench_json(
        "bench_out/BENCH_gemm.json",
        if quick { "quick" } else { "full" },
        &cases,
        arena_growth,
        tensor_allocs,
    )
    .expect("writing BENCH_gemm.json");
    println!("wrote bench_out/BENCH_gemm.json");
}
