//! E-fig2: Fig 2(a,b,c) — the impact of batch size and thread count on
//! the GEMM kernel.
//!
//! * (a) speedup vs #threads at several batch sizes — device model
//!   (this testbed has 1 core; the model's efficiency curve is
//!   calibrated from the measured single-core numbers below).
//! * (b) speedup vs batch size at 8 threads — model, plus the
//!   *measured* single-core GFLOP/s of thin-vs-fat lowered matrices
//!   (the mechanism).
//! * (c) memory footprint vs batch size — exact (workspace bytes).
//! * (d) planned-workspace execution — tensor allocations per training
//!   step before vs after the first (planning) step, measured via the
//!   `tensor::alloc_stats` hook: the hot loop is allocation-free.
//!
//! Run: `cargo bench --bench fig2_gemm_batching`

use cct::bench_util::{bench, gflops, Table};
use cct::device::profiles;
use cct::gemm::{gemm_flops, sgemm, GemmDims, Trans};
use cct::layers::ExecCtx;
use cct::lowering::{type1, ConvShape};
use cct::net::{config::build_net, parse_net, presets};
use cct::rng::Pcg64;
use cct::tensor::{alloc_stats, Tensor};

/// conv2's GEMM geometry (Fig 7): k²d = 2400, o = 256, m² = 529/image.
const COLS: usize = 2400;
const OUT: usize = 256;
const ROWS_PER_IMAGE: usize = 529;

fn measured_gflops(rows: usize, reps: usize) -> f64 {
    let mut rng = Pcg64::new(41);
    let mut a = vec![0f32; rows * COLS];
    let mut b = vec![0f32; COLS * OUT];
    rng.fill_uniform(&mut a, -1.0, 1.0);
    rng.fill_uniform(&mut b, -1.0, 1.0);
    let mut c = vec![0f32; rows * OUT];
    let dims = GemmDims { m: rows, n: OUT, k: COLS };
    let st = bench(1, reps, || {
        sgemm(Trans::N, Trans::N, dims, 1.0, &a, &b, 0.0, &mut c, 1);
    });
    gflops(gemm_flops(dims), st.min)
}

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    let dev = profiles::c4_4xlarge();
    let flops_per_image = gemm_flops(GemmDims { m: ROWS_PER_IMAGE, n: OUT, k: COLS });

    // ---- (a) speedup vs threads, per batch size (model) ------------
    let mut ta = Table::new(
        "Fig 2(a/b) model: GEMM speedup vs threads (c4.4xlarge model, conv2 GEMM)",
        &["batch", "t=1", "t=2", "t=4", "t=8"],
    );
    for b in [1usize, 4, 16, 64, 256] {
        let rows = b * ROWS_PER_IMAGE;
        let flops = flops_per_image * b as u64;
        let t1 = dev.gemm_seconds(flops, rows, 1);
        let row: Vec<String> = [1usize, 2, 4, 8]
            .iter()
            .map(|&t| format!("{:.2}×", t1 / dev.gemm_seconds(flops, rows, t)))
            .collect();
        ta.row(&[b.to_string(), row[0].clone(), row[1].clone(), row[2].clone(), row[3].clone()]);
    }
    ta.print();
    ta.write_csv("bench_out/fig2a_model.csv").ok();
    println!("paper Fig 2(a): near-linear to 4 cores at b=256; Fig 2(b): smaller b ⇒ lower speedup.");

    // ---- (b) measured single-core: thin vs fat lowered matrices ----
    let mut tb = Table::new(
        "Fig 2(b) measured (this machine, 1 core): GEMM throughput vs lowered batch",
        &["batch (rows)", "GFLOP/s", "vs b=1"],
    );
    let base = measured_gflops(ROWS_PER_IMAGE, 3);
    let mut rows_csv = Vec::new();
    for b in [1usize, 2, 4, 8, 16] {
        let g = if b == 1 { base } else { measured_gflops(b * ROWS_PER_IMAGE, 2) };
        tb.row(&[
            format!("{b} ({})", b * ROWS_PER_IMAGE),
            format!("{g:.2}"),
            format!("{:.2}×", g / base),
        ]);
        rows_csv.push((b, g));
    }
    tb.print();
    tb.write_csv("bench_out/fig2b_measured.csv").ok();

    // ---- (c) memory footprint vs batch (exact) ---------------------
    let mut tc = Table::new(
        "Fig 2(c): lowered-matrix memory footprint vs batch (conv2, exact)",
        &["batch", "lowered MiB", "vs b=1"],
    );
    let bytes1 = type1::Workspace::new(&ConvShape { n: 27, k: 5, d: 96, o: 256, b: 1, pad: 2, stride: 1 }).bytes();
    for b in [1usize, 16, 64, 128, 256] {
        let shape = ConvShape { n: 27, k: 5, d: 96, o: 256, b, pad: 2, stride: 1 };
        let bytes = type1::Workspace::new(&shape).bytes();
        tc.row(&[
            b.to_string(),
            format!("{:.1}", bytes as f64 / (1 << 20) as f64),
            format!("{:.0}×", bytes as f64 / bytes1 as f64),
        ]);
    }
    tc.print();
    tc.write_csv("bench_out/fig2c_footprint.csv").ok();
    println!("paper Fig 2(c): footprint directly proportional to b.");

    // ---- (d) plan-once / run-many: tensor allocs per step ----------
    let cfg = parse_net(presets::CIFAR10_QUICK).expect("preset parses");
    let mut rng = Pcg64::new(42);
    let mut net = build_net(&cfg, &mut rng).expect("preset builds");
    let x = Tensor::randn((16, 3, 32, 32), 0.0, 1.0, &mut rng);
    let labels: Vec<usize> = (0..16).map(|i| i % 10).collect();
    let ctx = ExecCtx::default();
    let mut td = Table::new(
        "Plan-once/run-many: tensor allocations per forward_backward (cifar10_quick, b=16)",
        &["step", "tensor allocs"],
    );
    for step in 0..4 {
        let snap = alloc_stats::tensor_allocs();
        let _ = net.forward_backward(&x, &labels, &ctx);
        td.row(&[
            if step == 0 { "1 (plans workspace)".into() } else { format!("{}", step + 1) },
            alloc_stats::allocs_since(snap).to_string(),
        ]);
    }
    td.print();
    println!("steps after the first run entirely inside the planned arena (0 allocs).");
}
