//! E-fig9: Fig 9 — the impact of the GPU task fraction p on hybrid
//! speedup, with the FLOPS-proportional heuristic's pick and the
//! sweep-optimal marked. Device-model simulation (g2.2xlarge fleet).
//!
//! Run: `cargo bench --bench fig9_sched_ratio`

use cct::bench_util::Table;
use cct::coordinator::scheduler;
use cct::device::profiles;
use cct::lowering::{ConvShape, LoweringType};

fn main() {
    std::fs::create_dir_all("bench_out").ok();
    let gpu = profiles::grid_k520();
    let cpu = profiles::g2_host_cpu();
    let shape = ConvShape { n: 227, k: 11, d: 3, o: 96, b: 256, pad: 0, stride: 4 };

    let gpu_only =
        scheduler::simulate_hybrid_conv(&shape, &[gpu.clone()], &[256], LoweringType::Type1).makespan_s;

    let mut t = Table::new(
        "Fig 9: speedup vs GPU task fraction p (conv1, g2.2xlarge model)",
        &["p (gpu share)", "makespan", "speedup vs GPU-only"],
    );
    for pct in (0..=100).step_by(5) {
        let on_gpu = (256 * pct) / 100;
        let plan = scheduler::simulate_hybrid_conv(
            &shape,
            &[gpu.clone(), cpu.clone()],
            &[on_gpu, 256 - on_gpu],
            LoweringType::Type1,
        );
        t.row(&[
            format!("{pct}%"),
            format!("{:.4}s", plan.makespan_s),
            format!("{:.3}×", gpu_only / plan.makespan_s),
        ]);
    }
    t.print();
    t.write_csv("bench_out/fig9.csv").ok();

    // heuristic pick vs sweep optimum
    let heuristic = scheduler::flops_proportional_split(256, &[gpu.clone(), cpu.clone()]);
    let h_plan = scheduler::simulate_hybrid_conv(
        &shape,
        &[gpu.clone(), cpu.clone()],
        &heuristic,
        LoweringType::Type1,
    );
    let (p_opt, opt) = scheduler::optimal_two_device_split(&shape, &[gpu, cpu], LoweringType::Type1);
    println!(
        "\nheuristic p = {:.1}% → {:.3}×;  sweep-optimal p = {:.1}% → {:.3}×;  gap = {:.1}%",
        heuristic[0] as f64 / 2.56,
        gpu_only / h_plan.makespan_s,
        p_opt * 100.0,
        gpu_only / opt.makespan_s,
        (h_plan.makespan_s / opt.makespan_s - 1.0) * 100.0
    );
    println!("paper: optimal p ≈ 83%, heuristic within 5% (both estimates).");
}
