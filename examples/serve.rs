//! Serving quickstart: the dynamic micro-batching inference engine in
//! ~60 lines.
//!
//! ```sh
//! cargo run --release --example serve
//! ```
//!
//! Starts a [`ServeEngine`] over a small net, drives it from a few
//! concurrent client threads (blocking and non-blocking submission,
//! including the backpressure path), exercises the QoS surface
//! (priority lanes, deadlines, shedding), then shuts down and prints
//! the latency/throughput report.
//!
//! For the HTTP transport in front of the same engine, run
//! `cargo run --release -- serve` and POST to `/infer`.

use cct::device::profiles;
use cct::net::parse_net;
use cct::serve::{
    plan_bucket_ladder, worker_placement, InferOptions, InferOutcome, Lane, ServeConfig,
    ServeEngine, SubmitError,
};

const NET: &str = r#"
name: servedemo
input: 3 16 16
conv { name: conv1 out: 16 kernel: 3 pad: 1 std: 0.1 }
relu { name: relu1 }
pool { name: pool1 mode: max kernel: 2 stride: 2 }
fc   { name: fc1 out: 10 std: 0.1 }
"#;

fn main() -> cct::Result<()> {
    // 1. Start the engine: 2 workers, micro-batches of up to 8, a
    //    request waits at most 1 ms for company. Each worker pre-plans
    //    forward-only workspaces at every bucket size, so the serving
    //    steady state allocates no tensors.
    let cfg = parse_net(NET)?;
    let engine = ServeEngine::start(
        &cfg,
        ServeConfig { workers: 2, max_batch: 8, max_wait_us: 1_000, ..Default::default() },
    )?;
    println!("bucket ladder: {:?}", engine.buckets());

    // 2. Concurrent clients. Blocking `infer` applies backpressure by
    //    waiting; `try_infer` rejects immediately when the bounded
    //    queue is full — shed load instead of growing memory.
    std::thread::scope(|scope| {
        for client in 0..4 {
            let handle = engine.handle();
            let sample_len = engine.sample_len();
            scope.spawn(move || {
                let sample = vec![0.1 * (client as f32 + 1.0); sample_len];
                for i in 0..50 {
                    if i % 10 == 9 {
                        // Non-blocking path with explicit rejection handling.
                        match handle.try_infer(&sample) {
                            Ok(pending) => {
                                let reply = pending.wait().expect("engine answered");
                                assert!(reply.class < 10);
                            }
                            Err(SubmitError::QueueFull) => { /* shed this request */ }
                            Err(_) => return, // engine closed / bad input
                        }
                    } else {
                        let reply = handle.infer(&sample).expect("engine answered");
                        assert_eq!(reply.logits.len(), 10);
                    }
                }
            });
        }
    });

    // 3. The QoS surface. Best-effort requests fill leftover batch
    //    capacity; a deadline bounds how stale a request may get
    //    before it is shed instead of executed.
    let handle = engine.handle();
    let sample = vec![0.3f32; engine.sample_len()];
    let be = handle
        .infer_with(&sample, InferOptions::best_effort())
        .expect("best-effort request answered");
    println!("best-effort reply: class {} on lane {:?}", be.class, be.lane);
    // A zero deadline is expired on arrival — the engine answers
    // `Expired` without spending a single FLOP on it.
    let doomed = handle
        .try_infer_with(&sample, InferOptions::default().with_deadline_us(0))
        .expect("queue has room");
    match doomed.wait_outcome().expect("engine answers sheds too") {
        InferOutcome::Expired => println!("zero-deadline request was shed (as intended)"),
        InferOutcome::Reply(r) => println!("unexpectedly served: class {}", r.class),
    }

    // 4. Shut down and read the report.
    let report = engine.shutdown();
    println!(
        "served {} requests in {:.2}s ({:.0} req/s), mean batch {:.2}, {} rejected, {} expired",
        report.completed,
        report.wall_s,
        report.throughput_rps,
        report.mean_batch,
        report.rejected,
        report.expired
    );
    println!(
        "latency p50/p95/p99: {:.2}/{:.2}/{:.2} ms  (interactive p99 {:.2} ms, best-effort p99 {:.2} ms)",
        report.latency.p50_us / 1e3,
        report.latency.p95_us / 1e3,
        report.latency.p99_us / 1e3,
        report.lane(Lane::Interactive).latency.p99_us / 1e3,
        report.lane(Lane::BestEffort).latency.p99_us / 1e3
    );
    println!("steady-state tensor allocs per worker: {:?}", report.worker_steady_allocs);

    // 5. The planning helpers on their own: a cost-model bucket ladder
    //    and FLOPS-proportional worker placement (paper §2.2/§2.3).
    let dev = profiles::c4_4xlarge();
    let ladder = plan_bucket_ladder(50_000_000, 64, 64, &dev, 4);
    println!("cost-model ladder for a 50 MFLOP/image net on c4.4xlarge (4 threads): {ladder:?}");
    let fleet = [profiles::grid_k520(), profiles::g2_host_cpu()];
    println!("8 workers over [K520, host CPU]: {:?}", worker_placement(8, &fleet));
    Ok(())
}
