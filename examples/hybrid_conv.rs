//! Hybrid CPU+GPU scheduling demo (paper §2.3 / Fig 4(a)).
//!
//! ```sh
//! cargo run --release --example hybrid_conv
//! ```
//!
//! Two parts:
//!
//! 1. **Real partitioned execution** — runs CaffeNet's conv2 over a
//!    mini-batch under each batching strategy (Caffe per-image vs CcT
//!    full-batch vs partitioned) on this machine and prints measured
//!    wall times.
//! 2. **Fleet simulation** — replays the paper's g2.2xlarge experiment
//!    (GRID K520 + 4-core host CPU) through the calibrated device
//!    model: GPU-only vs FLOPS-proportional hybrid on conv1 at both
//!    grouping depths, like Fig 4(a).

use cct::bench_util::{fmt_secs, Table};
use cct::coordinator::{conv_partitioned, scheduler, BatchStrategy};
use cct::device::profiles;
use cct::lowering::{ConvShape, LoweringType};
use cct::rng::Pcg64;
use cct::tensor::Tensor;

fn main() -> cct::Result<()> {
    // --- Part 1: measured batching strategies on this machine ------
    let shape = ConvShape { n: 27, k: 5, d: 96, o: 64, b: 16, pad: 2, stride: 1 };
    let mut rng = Pcg64::new(1);
    let data = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
    let w = Tensor::randn(shape.weight_shape(), 0.0, 0.05, &mut rng);

    let mut t = Table::new(
        "Measured: conv2-like layer under batching strategies (this machine)",
        &["strategy", "partitions", "wall", "lowered MiB"],
    );
    for strategy in [
        BatchStrategy::CaffeStyle,
        BatchStrategy::FullBatch,
        BatchStrategy::Partitions(2),
        BatchStrategy::Partitions(4),
    ] {
        let (_, stats) = conv_partitioned(&shape, &data, &w, strategy, 4);
        t.row(&[
            strategy.to_string(),
            stats.partitions.to_string(),
            fmt_secs(stats.wall_s),
            format!("{:.1}", stats.lowered_bytes as f64 / (1 << 20) as f64),
        ]);
    }
    t.print();

    // --- Part 2: simulated g2.2xlarge hybrid (Fig 4a) --------------
    let gpu = profiles::grid_k520();
    let cpu = profiles::g2_host_cpu();
    let mut t = Table::new(
        "Simulated: conv1 on g2.2xlarge — GPU vs CPU+GPU hybrid (Fig 4a)",
        &["config", "depth", "time", "speedup vs GPU", "gpu share"],
    );
    for (group, depth) in [(1usize, 48usize), (2, 96)] {
        // Fig 4(a): conv1 with grouping 1 (depth=48) and 2 (depth=96).
        let shape = ConvShape { n: 227, k: 11, d: 3, o: depth / group.max(1), b: 256, pad: 0, stride: 4 };
        let gpu_only = scheduler::simulate_hybrid_conv(&shape, &[gpu.clone()], &[256], LoweringType::Type1);
        let hybrid = scheduler::schedule_and_simulate(&shape, &[gpu.clone(), cpu.clone()], LoweringType::Type1);
        let share = hybrid.assignment[0] as f64 / 256.0;
        t.row(&[
            "GPU only".into(),
            depth.to_string(),
            fmt_secs(gpu_only.makespan_s),
            "1.00×".into(),
            "100%".into(),
        ]);
        t.row(&[
            "CPU+GPU".into(),
            depth.to_string(),
            fmt_secs(hybrid.makespan_s),
            format!("{:.2}×", gpu_only.makespan_s / hybrid.makespan_s),
            format!("{:.0}%", share * 100.0),
        ]);
    }
    t.print();
    println!("\npaper: hybrid ≈ 1.20× with an 85% GPU share (Fig 4a)");
    Ok(())
}
