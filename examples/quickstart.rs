//! Quickstart: the public API in ~60 lines.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a small Caffe-style net from a config string, trains it a few
//! steps with the data-parallel coordinator, and asks the paper's
//! lowering optimizer what it would do on AlexNet's conv layers.

use cct::coordinator::CnnCoordinator;
use cct::data::BlobCorpus;
use cct::lowering::{choose_lowering, ConvShape, MachineProfile};
use cct::net::parse_net;
use cct::solver::SolverConfig;

const NET: &str = r#"
name: quickstart
input: 3 16 16
conv { name: conv1 out: 16 kernel: 3 pad: 1 std: 0.1 }
relu { name: relu1 }
pool { name: pool1 mode: max kernel: 2 stride: 2 }
fc   { name: fc1 out: 10 std: 0.1 }
softmax { name: loss }
"#;

fn main() -> anyhow::Result<()> {
    // 1. Parse a Caffe-style net description and build a coordinator
    //    with 2 data-parallel workers (paper §2.2: batch partitioning).
    let cfg = parse_net(NET)?;
    let solver = SolverConfig { base_lr: 0.05, ..Default::default() };
    let mut coord = CnnCoordinator::new(&cfg, /*workers=*/ 2, /*threads=*/ 2, solver, 42)?;

    // 2. A learnable synthetic corpus (10 classes of structured blobs).
    let mut corpus = BlobCorpus::generate(3, 16, 10, 256, 0.2, 7);

    // 3. Train.
    for step in 0..30 {
        let (x, labels) = corpus.next_batch(32);
        let loss = coord.step(&x, &labels);
        if step % 10 == 0 {
            println!("step {step:>3}  loss {loss:.4}");
        }
    }

    // 4. The paper's automatic lowering optimizer (Appendix A): which
    //    blocking would it pick per conv shape?
    let machine = MachineProfile::one_core();
    for (name, shape) in [
        ("conv2-like (d/o = 0.38)", ConvShape::simple(27, 5, 96, 256, 16)),
        ("few-output-channels (d/o = 32)", ConvShape::simple(13, 3, 512, 16, 16)),
    ] {
        println!("{name}: optimizer picks {}", choose_lowering(&shape, &machine));
    }
    Ok(())
}
