//! Quickstart: the public API in ~70 lines.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a small Caffe-style net from a config string, shows the
//! plan-once / run-many workspace API (the zero-allocation training
//! hot loop), trains the same architecture with the data-parallel
//! coordinator, and asks the paper's lowering optimizer what it would
//! do on AlexNet's conv layers.

use cct::coordinator::CnnCoordinator;
use cct::data::BlobCorpus;
use cct::layers::ExecCtx;
use cct::lowering::{choose_lowering, ConvShape, MachineProfile};
use cct::net::{config::build_net, parse_net};
use cct::rng::Pcg64;
use cct::solver::{SgdSolver, SolverConfig};

const NET: &str = r#"
name: quickstart
input: 3 16 16
conv { name: conv1 out: 16 kernel: 3 pad: 1 std: 0.1 }
relu { name: relu1 }
pool { name: pool1 mode: max kernel: 2 stride: 2 }
fc   { name: fc1 out: 10 std: 0.1 }
softmax { name: loss }
"#;

fn main() -> cct::Result<()> {
    // 1. Parse a Caffe-style net description.
    let cfg = parse_net(NET)?;

    // 2. A learnable synthetic corpus (10 classes of structured blobs).
    let mut corpus = BlobCorpus::generate(3, 16, 10, 256, 0.2, 7);

    // 3. Plan once, run many: the workspace holds the activation +
    //    gradient arenas and all conv lowering scratch, sized by one
    //    shape walk — every subsequent step is allocation-free.
    let mut rng = Pcg64::new(42);
    let mut net = build_net(&cfg, &mut rng)?;
    let batch = 32;
    let mut ws = net.plan(batch);
    println!("planned workspace: {} slots, {:.1} KiB", ws.num_slots(), ws.bytes() as f64 / 1024.0);

    let mut solver = SgdSolver::new(SolverConfig { base_lr: 0.05, ..Default::default() });
    let ctx = ExecCtx::default();
    for step in 0..30 {
        let (x, labels) = corpus.next_batch(batch);
        ws.load_input(&x);
        let loss = solver.train_step_in(&mut net, &mut ws, &labels, &ctx);
        if step % 10 == 0 {
            println!("step {step:>3}  loss {loss:.4}");
        }
    }

    // 4. The same training through the data-parallel coordinator
    //    (paper §2.2: batch partitioning — each partition gets its own
    //    workspace on its own worker thread).
    let solver_cfg = SolverConfig { base_lr: 0.05, ..Default::default() };
    let mut coord = CnnCoordinator::new(&cfg, /*workers=*/ 2, /*threads=*/ 2, solver_cfg, 42)?;
    for step in 0..30 {
        let (x, labels) = corpus.next_batch(batch);
        let loss = coord.step(&x, &labels);
        if step % 10 == 0 {
            println!("coord step {step:>3}  loss {loss:.4}");
        }
    }

    // 5. The paper's automatic lowering optimizer (Appendix A): which
    //    blocking would it pick per conv shape?
    let machine = MachineProfile::one_core();
    for (name, shape) in [
        ("conv2-like (d/o = 0.38)", ConvShape::simple(27, 5, 96, 256, 16)),
        ("few-output-channels (d/o = 32)", ConvShape::simple(13, 3, 512, 16, 16)),
    ] {
        println!("{name}: optimizer picks {}", choose_lowering(&shape, &machine));
    }
    Ok(())
}
