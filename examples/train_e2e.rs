//! End-to-end validation driver (EXPERIMENTS.md E-e2e).
//!
//! ```sh
//! cargo run --release --example train_e2e
//! ```
//!
//! Proves the stack composes on a real (small) workload:
//!
//! **Phase A — native engine**: train Caffe's `cifar10_quick` network
//! (3×32×32, 10 classes) on a learnable synthetic corpus for several
//! hundred data-parallel coordinator steps (each batch partition runs
//! in its own planned workspace — the allocation-free hot loop); log
//! the loss curve and final accuracy.
//!
//! **Phase B — XLA engine**: run the AOT-compiled `train_step` HLO
//! artifact (JAX fwd/bwd with the Pallas Type-1 conv kernel inside)
//! from the Rust runtime. Skipped gracefully when the artifacts or
//! the PJRT backend are unavailable (this dependency-free build has
//! no PJRT client linked — see `cct::runtime`).
//!
//! Loss curves are written to bench_out/e2e_*.csv and summarized on
//! stdout; EXPERIMENTS.md records a reference run.

use cct::coordinator::CnnCoordinator;
use cct::data::BlobCorpus;
use cct::ensure;
use cct::layers::{ExecCtx, Phase};
use cct::net::{parse_net, presets};
use cct::rng::Pcg64;
use cct::runtime::{ArtifactStore, XlaInput};
use cct::solver::SolverConfig;
use cct::tensor::Tensor;
use std::time::Instant;

fn write_csv(path: &str, header: &str, rows: &[(usize, f64)]) -> std::io::Result<()> {
    std::fs::create_dir_all("bench_out")?;
    let mut s = String::from(header);
    s.push('\n');
    for (i, v) in rows {
        s.push_str(&format!("{i},{v}\n"));
    }
    std::fs::write(path, s)
}

fn phase_a(steps: usize) -> cct::Result<()> {
    println!("=== Phase A: native engine — cifar10_quick, {steps} steps ===");
    let cfg = parse_net(presets::CIFAR10_QUICK)?;
    let solver = SolverConfig { base_lr: 0.02, momentum: 0.9, weight_decay: 1e-4, ..Default::default() };
    let mut coord = CnnCoordinator::new(&cfg, /*workers=*/ 2, 2, solver, 1)?;
    let mut corpus = BlobCorpus::generate(3, 32, 10, 512, 0.3, 11);

    let batch = 32;
    let mut curve = Vec::new();
    let t0 = Instant::now();
    for step in 0..steps {
        let (x, labels) = corpus.next_batch(batch);
        let loss = coord.step(&x, &labels);
        curve.push((step, loss));
        if step % 25 == 0 || step + 1 == steps {
            println!(
                "  step {step:>4}  loss {loss:.4}  ({:.1} img/s)",
                batch as f64 * (step + 1) as f64 / t0.elapsed().as_secs_f64()
            );
        }
    }
    // Eval on a fixed slice.
    let (ex, ey) = corpus.eval_batch(128);
    let ctx = ExecCtx { phase: Phase::Test, ..Default::default() };
    coord.net().forward_loss(&ex, &ey, &ctx);
    let acc = coord.net().last_accuracy();
    println!("  final eval accuracy: {:.1}% (chance = 10%)", acc * 100.0);
    write_csv("bench_out/e2e_native_loss.csv", "step,loss", &curve)?;
    let first = curve.first().unwrap().1;
    let last = curve.last().unwrap().1;
    ensure!(last < first * 0.5, "native loss did not halve: {first} → {last}");
    Ok(())
}

fn phase_b(steps: usize) -> cct::Result<()> {
    println!("=== Phase B: XLA engine — AOT train_step via PJRT, {steps} steps ===");
    let mut store = match ArtifactStore::open("artifacts") {
        Ok(s) => s,
        Err(e) => {
            println!("  SKIP: {e} (run `make artifacts` with a PJRT-enabled build)");
            return Ok(());
        }
    };
    println!("  platform: {}", store.platform());
    let (b, classes) = (32usize, 10usize);
    let mut rng = Pcg64::new(2);
    let mut params: Vec<Tensor> = vec![
        Tensor::randn((8, 3, 3, 3), 0.0, 0.1, &mut rng),
        Tensor::zeros(8usize),
        Tensor::randn((classes, 8 * 8 * 8), 0.0, 0.05, &mut rng),
        Tensor::zeros(classes),
    ];
    let mut corpus = BlobCorpus::generate(3, 16, classes, 512, 0.25, 13);
    let art = match store.load("train_step") {
        Ok(a) => a,
        Err(e) => {
            println!("  SKIP: {e}");
            return Ok(());
        }
    };
    let mut curve = Vec::new();
    let t0 = Instant::now();
    for step in 0..steps {
        let (x, labels) = corpus.next_batch(b);
        let y: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
        let mut inputs: Vec<XlaInput> = params.iter().cloned().map(XlaInput::F32).collect();
        inputs.push(XlaInput::F32(x));
        inputs.push(XlaInput::I32(y));
        let mut out = art.run(&inputs)?;
        let loss = out.pop().unwrap().as_slice()[0] as f64;
        params = out;
        curve.push((step, loss));
        if step % 25 == 0 || step + 1 == steps {
            println!("  step {step:>4}  loss {loss:.4}");
        }
    }
    println!(
        "  {} steps in {:.2}s ({:.1} img/s), python never on the path",
        steps,
        t0.elapsed().as_secs_f64(),
        (steps * b) as f64 / t0.elapsed().as_secs_f64()
    );
    write_csv("bench_out/e2e_xla_loss.csv", "step,loss", &curve)?;
    let first = curve.first().unwrap().1;
    let last = curve.last().unwrap().1;
    ensure!(last < first * 0.6, "xla loss did not descend: {first} → {last}");
    Ok(())
}

fn main() -> cct::Result<()> {
    let steps_a: usize = std::env::var("E2E_STEPS_A").ok().and_then(|s| s.parse().ok()).unwrap_or(300);
    let steps_b: usize = std::env::var("E2E_STEPS_B").ok().and_then(|s| s.parse().ok()).unwrap_or(200);
    phase_a(steps_a)?;
    phase_b(steps_b)?;
    println!("OK: training ran end-to-end; curves in bench_out/");
    Ok(())
}
