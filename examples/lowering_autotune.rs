//! Lowering auto-tuner demo (paper Appendix A / Fig 8).
//!
//! ```sh
//! cargo run --release --example lowering_autotune
//! ```
//!
//! Measures all three lowering strategies *natively* on a family of
//! conv shapes with varying input/output channel ratio d/o, prints the
//! measured winner next to the cost-model optimizer's pick, and shows
//! the crossover the paper reports ("when the ratio increases, type 3
//! outperforms type 1, and vice versa").

use cct::bench_util::{bench, fmt_secs, Table};
use cct::lowering::{
    choose_lowering, conv_forward, ConvShape, LoweringType, MachineProfile,
};
use cct::rng::Pcg64;
use cct::tensor::Tensor;

fn measure(shape: &ConvShape, ty: LoweringType) -> f64 {
    let mut rng = Pcg64::new(9);
    let data = Tensor::randn(shape.input_shape(), 0.0, 1.0, &mut rng);
    let w = Tensor::randn(shape.weight_shape(), 0.0, 0.1, &mut rng);
    bench(1, 3, || {
        let _ = conv_forward(ty, shape, &data, &w, 1);
    })
    .min
}

fn main() {
    let machine = MachineProfile::one_core();
    let mut t = Table::new(
        "Lowering autotune: measured vs cost model (n=13, k=3, b=8, d·o = 16384)",
        &["d", "o", "d/o", "t1", "t2", "t3", "measured best", "optimizer pick"],
    );
    // Sweep the channel ratio at constant d·o, the paper's Fig 8(c) axis.
    for (d, o) in [(32usize, 512usize), (64, 256), (128, 128), (256, 64), (512, 32), (1024, 16)] {
        let shape = ConvShape::simple(13, 3, d, o, 8);
        let times: Vec<f64> = LoweringType::ALL.iter().map(|&ty| measure(&shape, ty)).collect();
        let best = LoweringType::ALL[times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0];
        let pick = choose_lowering(&shape, &machine);
        t.row(&[
            d.to_string(),
            o.to_string(),
            format!("{:.2}", d as f64 / o as f64),
            fmt_secs(times[0]),
            fmt_secs(times[1]),
            fmt_secs(times[2]),
            best.to_string(),
            pick.to_string(),
        ]);
    }
    t.print();
    println!("\npaper Fig 8(c): type 3 wins as d/o grows; type 1 wins as it shrinks.");
}
