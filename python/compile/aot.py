"""AOT exporter: lower the L2 JAX functions (with their L1 Pallas
kernels inlined) to HLO **text** artifacts the Rust runtime compiles
and executes through PJRT.

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension
0.5.1 (the version the published `xla` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts (written to --out-dir, default ../artifacts):

    train_step.hlo.txt   (params…, x, y) → (params'…, loss)
    infer.hlo.txt        (params…, x)    → (logits,)
    conv_fwd.hlo.txt     (x, w)          → (y,)   — conv2-scale Pallas conv
    manifest.txt         one line per artifact: name, arg shapes, result arity

Usage: python -m compile.aot [--out-dir DIR]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    Rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def shapes_str(specs):
    return ";".join(
        "x".join(map(str, s.shape)) + ":" + ("i32" if s.dtype == jnp.int32 else "f32")
        for s in specs
    )


# The standalone conv artifact's geometry: a conv2-scale problem
# (Fig 7 row scaled to this testbed: d=16, o=32, n=16, k=5).
CONV_ART = {"b": 8, "d": 16, "n": 16, "k": 5, "o": 32}


def artifacts():
    """(name, function, arg specs, result arity) for every artifact."""
    ps = model.param_shapes()
    params = [spec(ps[k]) for k in model.param_order()]
    x = spec((model.BATCH, model.IN_CHANNELS, model.SIDE, model.SIDE))
    y = spec((model.BATCH,), jnp.int32)
    ca = CONV_ART
    conv_x = spec((ca["b"], ca["d"], ca["n"], ca["n"]))
    conv_w = spec((ca["o"], ca["d"], ca["k"], ca["k"]))
    return [
        ("train_step", model.train_step, [*params, x, y], len(params) + 1),
        ("infer", model.infer, [*params, x], 1),
        ("conv_fwd", model.conv_layer, [conv_x, conv_w], 1),
    ]


def main():
    ap = argparse.ArgumentParser()
    default_out = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    ap.add_argument("--out-dir", default=default_out)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, fn, specs, n_results in artifacts():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name} args={shapes_str(specs)} results={n_results}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
