"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every Pallas kernel in `lowering.py` is pytest-checked against these
references; the references themselves are validated against hand
computations in `python/tests/test_kernel.py`.
"""

import jax
import jax.numpy as jnp


def conv_ref(x, w, *, pad=0, stride=1):
    """Direct convolution oracle: x (b,d,n,n), w (o,d,k,k) -> (b,o,m,m).

    Implemented with lax.conv_general_dilated — XLA's own convolution,
    the gold standard the paper's systems (Caffe/CcT) are validated
    against ("both systems produce the same output within 0.1%").
    """
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def im2col_ref(x, *, k, pad=0, stride=1):
    """Type-1 lowering oracle: x (b,d,n,n) -> D-hat (b*m*m, k*k*d).

    Row (bi*m*m + r*m + c), column ((ch*k + rk)*k + ck) — the layout the
    Rust engine and the Pallas kernel share.
    """
    b, d, n, _ = x.shape
    m = (n + 2 * pad - k) // stride + 1
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    # patches[rk][ck] has shape (b, d, m, m)
    rows = []
    for rk in range(k):
        for ck in range(k):
            rows.append(xp[:, :, rk : rk + stride * m : stride, ck : ck + stride * m : stride])
    # (k*k, b, d, m, m) -> (b, m, m, d, k*k) -> (b*m*m, d*k*k)
    stacked = jnp.stack(rows, axis=0).reshape(k, k, b, d, m, m)
    out = jnp.transpose(stacked, (2, 4, 5, 3, 0, 1))  # b, m, m, d, k, k
    return out.reshape(b * m * m, d * k * k)


def conv_via_im2col_ref(x, w, *, pad=0, stride=1):
    """Type-1 lowered convolution in pure jnp (lower -> GEMM -> lift)."""
    b, d, n, _ = x.shape
    o, _, k, _ = w.shape
    m = (n + 2 * pad - k) // stride + 1
    lowered = im2col_ref(x, k=k, pad=pad, stride=stride)       # (b*m*m, k*k*d)
    w2d = w.reshape(o, d * k * k)                               # (o, k*k*d)
    r_hat = lowered @ w2d.T                                     # (b*m*m, o)
    return jnp.transpose(r_hat.reshape(b, m * m, o), (0, 2, 1)).reshape(b, o, m, m)


def matmul_ref(a, b):
    """GEMM oracle."""
    return a @ b


def maxpool_ref(x, *, k, stride):
    """Max-pool oracle via reduce_window (Caffe ceil-mode not needed for
    the exported models, which use exact-fit windows)."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, k, k),
        window_strides=(1, 1, stride, stride),
        padding="VALID",
    )
