"""L1 Pallas kernels (build-time only; never imported at runtime)."""

from .lowering import (  # noqa: F401
    conv_type1,
    conv_type1_mxu_utilization,
    conv_type1_vmem_bytes,
    conv_type3,
    matmul_tiled,
)
