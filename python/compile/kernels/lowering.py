"""L1 Pallas kernels: lowering-based convolution + tiled GEMM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CPU
story is "make the lowered matrix fat enough to fill the BLAS blocking
hierarchy"; on TPU the same insight becomes "make the lowered panel
fill VMEM and feed the MXU systolic array". Concretely:

* `conv_type1` lowers one image per grid step into a `(m², k²d)` panel
  held in VMEM (the `BlockSpec` pins the image block), then issues a
  single `(m², k²d) × (k²d, o)` contraction — an MXU-shaped matmul with
  `preferred_element_type=f32`. Batching across the grid reproduces the
  paper's batched lowering: the weight panel stays resident while the
  data panels stream through, exactly the HBM↔VMEM schedule the paper
  implemented with threadblock-level BLAS batching.
* `conv_type3` is the expensive-lifting blocking: a channel-contraction
  GEMM on the *unexpanded* input followed by the k²-tap shift-add lift.
* `matmul_tiled` is the standalone MXU-tiled GEMM used by the FC layer
  and the GEMM micro-benchmarks (128×128 output tiles).

All kernels run `interpret=True` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU performance is estimated analytically in
DESIGN.md §Perf from VMEM footprints and MXU tile occupancy.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Flip to False only when compiling for a real TPU target.
INTERPRET = True


# --------------------------------------------------------------------------
# Type-1 (im2col) convolution
# --------------------------------------------------------------------------

def _conv_type1_kernel(x_ref, w_ref, o_ref, *, k, pad, stride, m):
    """One grid step = one image: lower to (m², k²d) in VMEM, contract
    against the resident (k²d, o) weight panel, store (o, m, m)."""
    x = x_ref[0]                      # (d, n, n) block in VMEM
    d = x.shape[0]
    n = x.shape[1]
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    # k² static shifted views, each (d, m, m): the im2col expansion.
    patches = []
    for rk in range(k):
        for ck in range(k):
            patches.append(
                jax.lax.slice(
                    x,
                    (0, rk, ck),
                    (d, rk + (m - 1) * stride + 1, ck + (m - 1) * stride + 1),
                    (1, stride, stride),
                )
            )
    # (k², d, m, m) → (m², d·k²) with column order (d, rk, ck)
    stacked = jnp.stack(patches, axis=0).reshape(k, k, d, m, m)
    lowered = jnp.transpose(stacked, (3, 4, 2, 0, 1)).reshape(m * m, d * k * k)
    w2d = w_ref[...].reshape(-1, d * k * k)  # (o, k²d)
    # MXU contraction; f32 accumulate.
    r_hat = jax.lax.dot_general(
        lowered,
        w2d,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                         # (m², o)
    o_ref[0] = jnp.transpose(r_hat, (1, 0)).reshape(w2d.shape[0], m, m).astype(o_ref.dtype)


def _conv_type1_pallas(x, w, pad, stride):
    b, d, n, _ = x.shape
    o, dw, k, _ = w.shape
    assert d == dw, f"channel mismatch {d} vs {dw}"
    m = (n + 2 * pad - k) // stride + 1
    kernel = functools.partial(_conv_type1_kernel, k=k, pad=pad, stride=stride, m=m)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, d, n, n), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((o, d, k, k), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, o, m, m), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, o, m, m), x.dtype),
        interpret=INTERPRET,
    )(x, w)


def _xla_conv(x, w, pad, stride):
    """XLA's native convolution — used only for the backward rule."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


@functools.lru_cache(maxsize=None)
def _conv_type1_op(pad, stride):
    """custom_vjp wrapper per (pad, stride): the Pallas kernel computes
    the forward; the backward delegates to XLA's conv adjoint (the
    Type-1 col2im adjoint — the same math the Rust engine's
    `conv_type1_backward` hand-implements)."""

    @jax.custom_vjp
    def op(x, w):
        return _conv_type1_pallas(x, w, pad, stride)

    def fwd(x, w):
        return _conv_type1_pallas(x, w, pad, stride), (x, w)

    def bwd(res, g):
        x, w = res
        _, vjp = jax.vjp(lambda xx, ww: _xla_conv(xx, ww, pad, stride), x, w)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


def conv_type1(x, w, *, pad=0, stride=1):
    """Batched Type-1 lowered convolution.

    x: (b, d, n, n); w: (o, d, k, k) → (b, o, m, m). Grid over images;
    the weight block is broadcast (index_map pins it), so it stays
    VMEM-resident across the batch sweep. Differentiable (custom VJP).
    """
    return _conv_type1_op(pad, stride)(x, w)


# --------------------------------------------------------------------------
# Type-3 (expensive lifting) convolution — paper's formal setting only
# --------------------------------------------------------------------------

def _conv_type3_kernel(x_ref, w_ref, o_ref, *, k, m):
    """One image: channel-contraction GEMM on the raw input (no k²
    blow-up in VMEM — the Type-3 selling point), then k²-tap lift."""
    x = x_ref[0]                          # (d, n, n)
    d, n, _ = x.shape
    o = o_ref.shape[1]
    # D̂ (n², d): pure layout permute — zero-copy in spirit.
    d_hat = jnp.transpose(x.reshape(d, n * n), (1, 0))
    # K̂ (d, o·k²)
    k_hat = jnp.transpose(w_ref[...].reshape(o, d, k * k), (1, 0, 2)).reshape(d, o * k * k)
    r_hat = jax.lax.dot_general(
        d_hat, k_hat, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).reshape(n, n, o, k, k)
    # Lift: R[j, r, c] = Σ_{i,jj} R̂[r+i, c+jj, j, i, jj]
    acc = jnp.zeros((o, m, m), dtype=jnp.float32)
    for i in range(k):
        for jj in range(k):
            acc = acc + jnp.transpose(
                jax.lax.slice(r_hat, (i, jj, 0, i, jj), (i + m, jj + m, o, i + 1, jj + 1))[
                    :, :, :, 0, 0
                ],
                (2, 0, 1),
            )
    o_ref[0] = acc.astype(o_ref.dtype)


def conv_type3(x, w):
    """Batched Type-3 lowered convolution (pad=0, stride=1)."""
    b, d, n, _ = x.shape
    o, dw, k, _ = w.shape
    assert d == dw
    m = n - k + 1
    kernel = functools.partial(_conv_type3_kernel, k=k, m=m)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, d, n, n), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((o, d, k, k), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, o, m, m), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, o, m, m), x.dtype),
        interpret=INTERPRET,
    )(x, w)


# --------------------------------------------------------------------------
# MXU-tiled GEMM
# --------------------------------------------------------------------------

def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def matmul_tiled(a, b, *, block_m=128, block_n=128):
    """C = A·B with (block_m × block_n) MXU output tiles; full-K panels
    stream through VMEM. Shapes need not be tile multiples (pallas pads
    edge blocks)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    bm, bn = min(block_m, m), min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=INTERPRET,
    )(a, b)


# --------------------------------------------------------------------------
# VMEM budgeting (the L1 "profile" under interpret mode — see §Perf)
# --------------------------------------------------------------------------

def conv_type1_vmem_bytes(b, d, n, k, o, pad=0, stride=1, dtype_bytes=4):
    """Estimated VMEM working set of one `conv_type1` grid step: input
    block + lowered panel + weight panel + output block. Used by the
    DESIGN.md §Perf roofline table (TPU VMEM budget ≈ 16 MiB/core)."""
    m = (n + 2 * pad - k) // stride + 1
    x_block = d * (n + 2 * pad) ** 2
    lowered = m * m * k * k * d
    weights = o * d * k * k
    out = o * m * m
    return dtype_bytes * (x_block + lowered + weights + out)


def conv_type1_mxu_utilization(d, k, o, m):
    """Fraction of 128×128 MXU tiles doing useful work for the per-image
    contraction (m², k²d) × (k²d, o) — the structural efficiency number
    reported in EXPERIMENTS.md §Perf."""
    def tile_eff(dim):
        tiles = -(-dim // 128)
        return dim / (tiles * 128)

    return tile_eff(m * m) * tile_eff(k * k * d) * tile_eff(o)
