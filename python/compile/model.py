"""L2: the JAX model — a small Caffe-style CNN whose conv layers call
the L1 Pallas kernels, plus the SGD train step that gets AOT-lowered to
an HLO artifact the Rust coordinator executes via PJRT.

The exported net mirrors Caffe's `cifar10_quick` head (conv → pool →
relu → fc) at a size the interpret-mode Pallas path executes quickly on
CPU: 3×16×16 inputs, one lowered conv, 2×2 max-pool, 10-way classifier.
The Rust side treats the artifact as a black-box `(params, batch) →
(params', loss)` function — Python never runs at training time.
"""

import jax
import jax.numpy as jnp

from .kernels import conv_type1

# ----------------------------------------------------------------------
# Model geometry (kept in one place: aot.py embeds it in the manifest,
# rust/src/runtime reads it back).
# ----------------------------------------------------------------------
BATCH = 32
IN_CHANNELS = 3
SIDE = 16
CONV_OUT = 8
KERNEL = 3
PAD = 1
CLASSES = 10
POOLED = SIDE // 2  # after 2×2/2 max-pool
FLAT = CONV_OUT * POOLED * POOLED
LR = 0.05


def init_params(seed=0):
    """Gaussian init matching the Rust engine's conventions."""
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "conv_w": 0.1 * jax.random.normal(k0, (CONV_OUT, IN_CHANNELS, KERNEL, KERNEL), jnp.float32),
        "conv_b": jnp.zeros((CONV_OUT,), jnp.float32),
        "fc_w": 0.05 * jax.random.normal(k1, (CLASSES, FLAT), jnp.float32),
        "fc_b": jnp.zeros((CLASSES,), jnp.float32),
    }


def param_order():
    """Stable flattening order for the HLO artifact signature."""
    return ["conv_w", "conv_b", "fc_w", "fc_b"]


def param_shapes():
    p = init_params()
    return {k: tuple(p[k].shape) for k in param_order()}


def forward(params, x):
    """Logits for x (b, 3, 16, 16) — conv (Pallas) → bias → relu →
    max-pool → fc."""
    h = conv_type1(x, params["conv_w"], pad=PAD, stride=1)
    h = h + params["conv_b"][None, :, None, None]
    h = jnp.maximum(h, 0.0)
    h = jax.lax.reduce_window(
        h, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )
    h = h.reshape(h.shape[0], -1)
    return h @ params["fc_w"].T + params["fc_b"]


def loss_fn(params, x, y):
    """Mean softmax cross-entropy; y is int32 labels (b,)."""
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def train_step(conv_w, conv_b, fc_w, fc_b, x, y):
    """One SGD step with a *flat* signature (stable HLO interface):
    (params…, x, y) → (params'…, loss)."""
    params = {"conv_w": conv_w, "conv_b": conv_b, "fc_w": fc_w, "fc_b": fc_b}
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new = [params[k] - LR * grads[k] for k in param_order()]
    return (*new, loss)


def infer(conv_w, conv_b, fc_w, fc_b, x):
    """Forward-only artifact: (params…, x) → logits."""
    params = {"conv_w": conv_w, "conv_b": conv_b, "fc_w": fc_w, "fc_b": fc_b}
    return (forward(params, x),)


def conv_layer(x, w):
    """Standalone conv-layer artifact (conv2-scale, Pallas Type 1) used
    by the runtime round-trip tests and the hybrid executor demo."""
    return (conv_type1(x, w, pad=0, stride=1),)
