"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compiled artifacts — if the
kernels match the references here, the HLO the Rust runtime executes is
computing the paper's convolution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_type1, conv_type3, matmul_tiled
from compile.kernels.lowering import (
    conv_type1_mxu_utilization,
    conv_type1_vmem_bytes,
)
from compile.kernels import ref


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ----------------------------------------------------------------------
# oracles themselves
# ----------------------------------------------------------------------

class TestReferences:
    def test_conv_ref_known_values(self):
        # 3×3 input, 2×2 identity-corner kernel (see Rust reference test)
        x = jnp.arange(1.0, 10.0).reshape(1, 1, 3, 3)
        w = jnp.array([[[[1.0, 0.0], [0.0, 1.0]]]])
        r = ref.conv_ref(x, w)
        np.testing.assert_allclose(r.reshape(-1), [6.0, 8.0, 12.0, 14.0])

    def test_im2col_ref_layout(self):
        x = jnp.arange(1.0, 10.0).reshape(1, 1, 3, 3)
        low = ref.im2col_ref(x, k=2)
        np.testing.assert_allclose(low[0], [1, 2, 4, 5])
        np.testing.assert_allclose(low[3], [5, 6, 8, 9])

    def test_conv_via_im2col_matches_direct(self):
        x = rand(0, (2, 3, 8, 8))
        w = rand(1, (4, 3, 3, 3))
        np.testing.assert_allclose(
            ref.conv_via_im2col_ref(x, w, pad=1, stride=2),
            ref.conv_ref(x, w, pad=1, stride=2),
            rtol=1e-4,
            atol=1e-4,
        )


# ----------------------------------------------------------------------
# pallas type-1 conv
# ----------------------------------------------------------------------

class TestConvType1:
    @pytest.mark.parametrize(
        "b,d,n,k,o,pad,stride",
        [
            (1, 1, 5, 3, 1, 0, 1),
            (2, 3, 8, 3, 4, 1, 1),
            (3, 2, 9, 3, 5, 1, 2),
            (2, 4, 7, 5, 3, 2, 1),
            (1, 3, 16, 11, 4, 0, 4),  # conv1-like stride
            (4, 8, 6, 1, 8, 0, 1),    # 1×1 conv
        ],
    )
    def test_matches_reference(self, b, d, n, k, o, pad, stride):
        x = rand(b * 31 + k, (b, d, n, n))
        w = rand(o * 17 + n, (o, d, k, k))
        got = conv_type1(x, w, pad=pad, stride=stride)
        want = ref.conv_ref(x, w, pad=pad, stride=stride)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 3),
        d=st.integers(1, 4),
        k=st.integers(1, 4),
        extra=st.integers(0, 5),
        o=st.integers(1, 4),
        pad=st.integers(0, 2),
        stride=st.integers(1, 2),
    )
    def test_hypothesis_sweep(self, b, d, k, extra, o, pad, stride):
        n = k + extra
        x = rand(7, (b, d, n, n))
        w = rand(9, (o, d, k, k))
        got = conv_type1(x, w, pad=pad, stride=stride)
        want = ref.conv_ref(x, w, pad=pad, stride=stride)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_jit_compatible(self):
        x = rand(3, (2, 3, 8, 8))
        w = rand(4, (4, 3, 3, 3))
        f = jax.jit(lambda a, b: conv_type1(a, b, pad=1, stride=1))
        np.testing.assert_allclose(f(x, w), ref.conv_ref(x, w, pad=1), rtol=1e-4, atol=1e-4)

    def test_gradable_through_kernel(self):
        # value_and_grad must flow through the pallas call (train_step
        # artifact depends on it).
        x = rand(5, (1, 2, 6, 6))
        w = rand(6, (3, 2, 3, 3))
        g = jax.grad(lambda w: jnp.sum(conv_type1(x, w, pad=1)))(w)
        gref = jax.grad(lambda w: jnp.sum(ref.conv_ref(x, w, pad=1)))(w)
        np.testing.assert_allclose(g, gref, rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------------------
# pallas type-3 conv
# ----------------------------------------------------------------------

class TestConvType3:
    @pytest.mark.parametrize(
        "b,d,n,k,o",
        [
            (1, 1, 5, 3, 1),
            (2, 3, 8, 3, 4),
            (2, 6, 7, 2, 2),
            (1, 8, 9, 1, 3),
            (3, 2, 6, 5, 2),
        ],
    )
    def test_matches_reference(self, b, d, n, k, o):
        x = rand(b + d, (b, d, n, n))
        w = rand(o + k, (o, d, k, k))
        got = conv_type3(x, w)
        want = ref.conv_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        d=st.integers(1, 6),
        k=st.integers(1, 3),
        extra=st.integers(0, 4),
        o=st.integers(1, 4),
    )
    def test_hypothesis_sweep(self, d, k, extra, o):
        n = k + extra
        x = rand(11, (2, d, n, n))
        w = rand(13, (o, d, k, k))
        np.testing.assert_allclose(
            conv_type3(x, w), ref.conv_ref(x, w), rtol=1e-3, atol=1e-3
        )

    def test_types_1_and_3_agree(self):
        # The paper's commutative diagram: all lowerings compute the
        # same R.
        x = rand(20, (2, 5, 9, 9))
        w = rand(21, (3, 5, 3, 3))
        np.testing.assert_allclose(
            conv_type1(x, w), conv_type3(x, w), rtol=1e-4, atol=1e-4
        )


# ----------------------------------------------------------------------
# tiled GEMM
# ----------------------------------------------------------------------

class TestMatmulTiled:
    @pytest.mark.parametrize(
        "m,k,n", [(4, 4, 4), (128, 64, 128), (130, 67, 31), (1, 256, 1), (256, 1, 256)]
    )
    def test_matches_reference(self, m, k, n):
        a = rand(m + n, (m, k))
        b = rand(k, (k, n))
        np.testing.assert_allclose(matmul_tiled(a, b), a @ b, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 200), k=st.integers(1, 64), n=st.integers(1, 200))
    def test_hypothesis_shapes(self, m, k, n):
        a = rand(1, (m, k))
        b = rand(2, (k, n))
        np.testing.assert_allclose(matmul_tiled(a, b), a @ b, rtol=1e-3, atol=1e-3)

    def test_bf16_inputs_f32_accumulate(self):
        a = rand(3, (64, 64)).astype(jnp.bfloat16)
        b = rand(4, (64, 64)).astype(jnp.bfloat16)
        got = matmul_tiled(a, b)
        assert got.dtype == jnp.bfloat16
        want = (a.astype(jnp.float32) @ b.astype(jnp.float32)).astype(jnp.bfloat16)
        np.testing.assert_allclose(
            got.astype(np.float32), want.astype(np.float32), rtol=5e-2, atol=5e-2
        )


# ----------------------------------------------------------------------
# VMEM / MXU structural profiles (the interpret-mode "perf" signal)
# ----------------------------------------------------------------------

class TestStructuralProfiles:
    def test_vmem_budget_of_export_shapes(self):
        # The shipped conv_fwd artifact must fit a 16 MiB VMEM core.
        from compile.aot import CONV_ART as ca

        bytes_ = conv_type1_vmem_bytes(1, ca["d"], ca["n"], ca["k"], ca["o"])
        assert bytes_ < 16 * 1024 * 1024

    def test_mxu_utilization_monotone_in_channels(self):
        # Fatter contraction dims fill MXU tiles better.
        low = conv_type1_mxu_utilization(d=3, k=3, o=8, m=8)
        high = conv_type1_mxu_utilization(d=64, k=3, o=128, m=16)
        assert 0.0 < low < high <= 1.0
