"""L2 correctness: model shapes, loss semantics, train-step descent,
and the AOT export path (everything the Rust runtime will consume)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def params():
    return model.init_params(seed=0)


def batch(seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (model.BATCH, model.IN_CHANNELS, model.SIDE, model.SIDE), jnp.float32)
    y = jax.random.randint(ky, (model.BATCH,), 0, model.CLASSES)
    return x, y


class TestModel:
    def test_forward_shape(self, params):
        x, _ = batch()
        logits = model.forward(params, x)
        assert logits.shape == (model.BATCH, model.CLASSES)

    def test_loss_is_log_classes_at_init_scale(self, params):
        # Near-random logits ⇒ loss ≈ ln(10).
        x, y = batch()
        loss = model.loss_fn(params, x, y)
        assert 0.5 * np.log(model.CLASSES) < float(loss) < 2.5 * np.log(model.CLASSES)

    def test_train_step_signature_and_descent(self, params):
        x, y = batch(1)
        flat = [params[k] for k in model.param_order()]
        out = model.train_step(*flat, x, y)
        assert len(out) == len(flat) + 1
        loss0 = float(out[-1])
        # iterate a few steps on the same batch: loss must fall
        cur = list(out[:-1])
        for _ in range(10):
            cur_out = model.train_step(*cur, x, y)
            cur = list(cur_out[:-1])
        lossN = float(cur_out[-1])
        assert lossN < loss0, f"{loss0} -> {lossN}"

    def test_infer_matches_forward(self, params):
        x, _ = batch(2)
        flat = [params[k] for k in model.param_order()]
        (logits,) = model.infer(*flat, x)
        np.testing.assert_allclose(logits, model.forward(params, x), rtol=1e-5, atol=1e-5)

    def test_param_shapes_consistent(self):
        shapes = model.param_shapes()
        assert shapes["conv_w"] == (model.CONV_OUT, model.IN_CHANNELS, model.KERNEL, model.KERNEL)
        assert shapes["fc_w"] == (model.CLASSES, model.FLAT)


class TestAotExport:
    def test_all_artifacts_lower_to_hlo_text(self, tmp_path):
        for name, fn, specs, _ in aot.artifacts():
            lowered = jax.jit(fn).lower(*specs)
            text = aot.to_hlo_text(lowered)
            assert text.startswith("HloModule"), f"{name}: not HLO text"
            assert "ENTRY" in text
            (tmp_path / f"{name}.hlo.txt").write_text(text)

    def test_manifest_format(self):
        arts = aot.artifacts()
        names = [a[0] for a in arts]
        assert names == ["train_step", "infer", "conv_fwd"]
        # train_step: 4 params + x + y args, 5 results
        assert len(arts[0][2]) == 6
        assert arts[0][3] == 5

    def test_conv_artifact_matches_oracle(self):
        # The exact function exported as conv_fwd.hlo.txt must equal the
        # XLA conv oracle on random inputs.
        from compile.kernels import ref

        ca = aot.CONV_ART
        x = jax.random.normal(jax.random.PRNGKey(5), (ca["b"], ca["d"], ca["n"], ca["n"]))
        w = jax.random.normal(jax.random.PRNGKey(6), (ca["o"], ca["d"], ca["k"], ca["k"]))
        (got,) = model.conv_layer(x, w)
        np.testing.assert_allclose(got, ref.conv_ref(x, w), rtol=1e-4, atol=1e-4)
